//! Wire codec: length-prefixed binary framing for every [`Message`]
//! variant plus the handshake/control frames.
//!
//! Frame layout (all integers little-endian):
//!
//! ```text
//! magic   u32   0x45584459 ("EXDY")
//! version u16   PROTOCOL_VERSION
//! kind    u8    frame discriminant
//! len     u32   payload byte count (<= MAX_PAYLOAD)
//! payload [u8; len]
//! check   u32   FNV-1a over magic..payload (header + payload)
//! ```
//!
//! Floats travel as their IEEE-754 bit patterns (`to_bits`/`from_bits`),
//! so NaN payloads round-trip bit-exactly — the parity guarantee of
//! `rust/tests/engine_parity.rs` survives the wire. Every decode error is
//! a typed [`Error::Protocol`], never a panic: corrupt lengths are capped
//! before allocation, declared element counts are checked against the
//! remaining frame bytes *before* any buffer is sized from them,
//! truncated buffers and trailing bytes are rejected, and the checksum
//! catches any single-byte flip (each FNV step is injective in both
//! arguments, so one flipped byte always changes the final hash).
//!
//! The hot path is bulk, not per-element: `u32`/`f32` arrays are
//! converted through 4-byte little-endian slabs in both directions
//! (chunked `to_le_bytes`/`from_le_bytes` over a pre-sized region, which
//! the compiler turns into straight memory moves on little-endian
//! targets), and the `*_append`/`*_with` entry points
//! ([`encode_frame_append`], [`read_frame_with`]) work in caller-owned
//! buffers so a steady-state peer reuses one encode and one decode
//! buffer instead of allocating per frame.

use crate::cluster::transport::Message;
use crate::collectives::SparseVec;
use crate::coordinator::SelectOutput;
use crate::error::{Error, Result};
use std::io::{Read, Write};
use std::sync::Arc;

/// Frame magic ("EXDY").
pub const MAGIC: u32 = 0x4558_4459;

/// Wire protocol version; bumped on any layout change (v2 added the
/// ring-rendezvous frames: `HelloRing`, `WelcomeRing`, `RingLink`; v3
/// added the reduce-scatter [`Frame::Shard`] frame; v4 added the truly
/// sparse forms: the [`Message::Sparse`] entry-list payload and the
/// [`Frame::SparseShard`] ring hop; v5 added elastic membership: the
/// [`Frame::Abort`] rank/generation stamp and the epoch re-rendezvous
/// frames [`Frame::HelloEpoch`], [`Frame::WelcomeEpoch`],
/// [`Frame::HelloJoin`]; v6 added coordinator succession: the hello
/// frames advertise the claimant's pre-bound standby listener port and
/// [`Frame::WelcomeEpoch`] carries the ordered succession address list
/// every member re-rendezvouses against when the coordinator itself
/// dies).
pub const PROTOCOL_VERSION: u16 = 6;

/// Sentinel for [`Frame::Abort`]'s `rank` when the aborting rank is
/// unknown (e.g. a poison observed without an identified source).
pub const ABORT_RANK_UNKNOWN: u32 = u32::MAX;

/// Hard cap on one frame's payload — guards allocation on corrupt
/// length fields (a selection frame at this size would be ~16M entries,
/// far beyond any workload in the repo).
pub const MAX_PAYLOAD: u32 = 1 << 28;

/// Header bytes before the payload: magic + version + kind + len.
pub const HEADER_LEN: usize = 4 + 2 + 1 + 4;

/// Everything that can cross the wire.
#[derive(Clone, Debug, PartialEq)]
pub enum Frame {
    /// One collective round's contribution or board entry. The
    /// generation counter lets both ends detect divergence/replay.
    Data {
        /// Round counter (must match the receiver's current round).
        generation: u64,
        /// The rank's message.
        msg: Message,
    },
    /// Client → hub rank claim.
    Hello {
        /// Claimed world size.
        world: u32,
        /// Claimed rank (1..world; rank 0 is the hub itself).
        rank: u32,
    },
    /// Hub → client: handshake accepted, cluster complete.
    Welcome {
        /// Confirmed world size.
        world: u32,
    },
    /// Hub → client: handshake refused.
    Reject {
        /// Human-readable refusal reason.
        reason: String,
    },
    /// Either direction: transport poisoned; the receiver must error
    /// out. Since v5 the notice is stamped with who aborted and at
    /// which round, so the receiver surfaces a typed
    /// [`Error::PeerLost`](crate::error::Error::PeerLost) instead of a
    /// stringly "peer aborted".
    Abort {
        /// The aborting rank, or [`ABORT_RANK_UNKNOWN`].
        rank: u32,
        /// The round generation the aborting rank was at.
        generation: u64,
    },
    /// Client → coordinator rank claim for the *ring* transport: like
    /// [`Frame::Hello`] plus the port of the claimant's own ring
    /// listener (the coordinator pairs it with the connection's source
    /// IP to build the neighbor table).
    HelloRing {
        /// Claimed world size.
        world: u32,
        /// Claimed rank (1..world; rank 0 is the coordinator itself).
        rank: u32,
        /// Port of the claimant's bound ring listener.
        port: u16,
    },
    /// Coordinator → client: ring rendezvous complete; dial your right
    /// neighbor at `right_addr` and accept your left neighbor on your
    /// own ring listener.
    WelcomeRing {
        /// Confirmed world size.
        world: u32,
        /// `host:port` of rank `(self + 1) % world`'s ring listener.
        right_addr: String,
    },
    /// Dialer → acceptor on a freshly-established ring link: identifies
    /// which rank is on the other end (the acceptor validates it is its
    /// left neighbor).
    RingLink {
        /// The dialing rank.
        rank: u32,
    },
    /// One reduce-scatter → all-gather hop on the ring: the partial (or,
    /// in the gather phase, fully reduced) values of one index chunk,
    /// forwarded right. `step` orders the hops within a round so a
    /// receiver can detect scheduling divergence, `chunk` names the
    /// index shard the values belong to ([`shard_bounds`]).
    ///
    /// [`shard_bounds`]: crate::collectives::shard_bounds
    Shard {
        /// Round counter (must match the receiver's current round).
        generation: u64,
        /// Hop number within the round's 2(n-1)-step schedule.
        step: u32,
        /// Which index shard these values belong to.
        chunk: u32,
        /// The chunk's values (partial sums or the reduced shard).
        vals: Vec<f32>,
    },
    /// One **sparse** reduce-scatter → all-gather hop (protocol v4,
    /// `--sparse-shards`): the `(index, value)` entries of one shard's
    /// partial (or reduced) list, forwarded right. Indices are
    /// *shard-local* (`global − shard_start`), strictly increasing and
    /// `< shard_len` — the decoder rejects anything else as a typed
    /// [`Error::Protocol`] before any reduce touches the entries.
    SparseShard {
        /// Round counter (must match the receiver's current round).
        generation: u64,
        /// Hop number within the round's 2(n-1)-step schedule.
        step: u32,
        /// Which index shard these entries belong to.
        chunk: u32,
        /// The shard's length — the exclusive bound every index must
        /// respect (carried so validation needs no out-of-band state).
        shard_len: u32,
        /// Shard-local positions, strictly increasing.
        idx: Vec<u32>,
        /// Values aligned with `idx`.
        vals: Vec<f32>,
    },
    /// Survivor → coordinator claim in an epoch re-rendezvous
    /// (protocol v5): after a membership fault the survivor reconnects
    /// to the bootstrap coordinator and reports which epoch it wants to
    /// form, its *original* rank, the next iteration it can resume
    /// from, and (ring only) the port of its freshly bound ring
    /// listener.
    HelloEpoch {
        /// The epoch the sender wants to form (current + 1).
        epoch: u64,
        /// The sender's original (epoch-0) rank.
        orig_rank: u32,
        /// First iteration the sender has not yet completed.
        next_t: u64,
        /// Port of the sender's new ring listener (0 for the star).
        port: u16,
        /// Port of the sender's pre-bound standby listener (protocol
        /// v6) — the socket it would coordinate the next epoch on if
        /// promoted. 0 = no standby advertised (never promotable).
        standby_port: u16,
    },
    /// Late joiner → coordinator (protocol v5): ask to be seated at the
    /// next epoch boundary. The coordinator parks the claim and forces
    /// a reform at its next iteration boundary.
    HelloJoin {
        /// The joiner's original rank (its synthetic gradient stream).
        orig_rank: u32,
        /// Port of the joiner's new ring listener (0 for the star).
        port: u16,
        /// Port of the joiner's pre-bound standby listener (protocol
        /// v6, see [`Frame::HelloEpoch::standby_port`]).
        standby_port: u16,
    },
    /// Coordinator → member: the epoch is formed (protocol v5; v6 adds
    /// the succession list). Carries the member's new dense rank, the
    /// full membership (original ranks in seat order), the iteration
    /// the epoch resumes at, the member's right-neighbor address (ring
    /// only, empty for the star), a sparsifier state snapshot for
    /// joiners (empty for survivors), and the ordered coordinator
    /// succession list.
    WelcomeEpoch {
        /// The epoch just formed.
        epoch: u64,
        /// The receiver's new dense rank within the epoch.
        rank: u32,
        /// Original ranks of every member, indexed by new dense rank.
        world: Vec<u32>,
        /// Iteration the epoch resumes at.
        resume_t: u64,
        /// `host:port` of the receiver's right ring neighbor ("" = star).
        right_addr: String,
        /// Opaque sparsifier state for joiners (empty for survivors).
        snapshot: Vec<u8>,
        /// Coordinator succession (protocol v6), indexed by seat: entry
        /// `i` is the `host:port` the member at seat `i` would
        /// coordinate the next re-rendezvous on — the current
        /// coordinator's own rendezvous address at its seat, each other
        /// member's standby listener at theirs ("" = that member
        /// advertised no standby and is skipped in the walk).
        succession: Vec<String>,
    },
}

impl Frame {
    /// Model-level payload bytes this frame carries — the units the
    /// [`ObsCounters`](crate::obs::ObsCounters) payload account and the
    /// [`CostModel`](crate::collectives::CostModel) link-byte
    /// predictions share: the message's entry bytes for [`Frame::Data`],
    /// 4 B per value for [`Frame::Shard`], and 0 for handshake/control
    /// frames (they move protocol state, not gradient payload). A
    /// [`Frame::SparseShard`] charges
    /// [`SPARSE_ENTRY_BYTES`](crate::collectives::CostModel::SPARSE_ENTRY_BYTES)
    /// per entry.
    pub fn payload_bytes(&self) -> usize {
        match self {
            Frame::Data { msg, .. } => msg.payload_bytes(),
            Frame::Shard { vals, .. } => {
                vals.len() * crate::collectives::CostModel::DENSE_ENTRY_BYTES
            }
            Frame::SparseShard { idx, .. } => {
                idx.len() * crate::collectives::CostModel::SPARSE_ENTRY_BYTES
            }
            _ => 0,
        }
    }
}

const KIND_DATA: u8 = 0;
const KIND_HELLO: u8 = 1;
const KIND_WELCOME: u8 = 2;
const KIND_REJECT: u8 = 3;
const KIND_ABORT: u8 = 4;
const KIND_HELLO_RING: u8 = 5;
const KIND_WELCOME_RING: u8 = 6;
const KIND_RING_LINK: u8 = 7;
const KIND_SHARD: u8 = 8;
const KIND_SPARSE_SHARD: u8 = 9;
const KIND_HELLO_EPOCH: u8 = 10;
const KIND_HELLO_JOIN: u8 = 11;
const KIND_WELCOME_EPOCH: u8 = 12;

const MSG_SELECTION: u8 = 0;
const MSG_FLOATS: u8 = 1;
const MSG_SCALAR: u8 = 2;
const MSG_SPARSE: u8 = 3;

/// Validate a decoded sparse index slab: strictly increasing and, when
/// the exclusive `bound` is known, within it. Runs *before* any reduce
/// touches the entries, so a hostile or bit-flipped frame dies here as
/// a typed [`Error::Protocol`], never as a panic deeper in the shard
/// arithmetic. (Indices being sorted, the last one is the maximum — one
/// comparison settles the bound.)
fn check_sparse_idx(idx: &[u32], bound: Option<u32>, what: &str) -> Result<()> {
    if let Some(bad) = idx.windows(2).find(|w| w[0] >= w[1]) {
        return Err(Error::protocol(format!(
            "{what} indices must be strictly increasing (got {} then {})",
            bad[0], bad[1]
        )));
    }
    if let (Some(b), Some(&last)) = (bound, idx.last()) {
        if last >= b {
            return Err(Error::protocol(format!(
                "{what} index {last} out of shard bounds (shard_len {b})"
            )));
        }
    }
    Ok(())
}

const FNV_SEED: u32 = 0x811C_9DC5;

fn fnv1a_update(mut h: u32, bytes: &[u8]) -> u32 {
    for &b in bytes {
        h ^= b as u32;
        h = h.wrapping_mul(16_777_619);
    }
    h
}

fn fnv1a(bytes: &[u8]) -> u32 {
    fnv1a_update(FNV_SEED, bytes)
}

fn put_u16(buf: &mut Vec<u8>, v: u16) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(buf: &mut Vec<u8>, v: f64) {
    put_u64(buf, v.to_bits());
}

/// Append `vals` as a little-endian 4-byte-per-element slab: one resize,
/// then straight chunked stores (byte-identical to the per-element
/// `put_u32` loop it replaces, but vectorizable).
fn put_u32_slab(buf: &mut Vec<u8>, vals: &[u32]) {
    let start = buf.len();
    buf.resize(start + 4 * vals.len(), 0);
    for (dst, v) in buf[start..].chunks_exact_mut(4).zip(vals) {
        dst.copy_from_slice(&v.to_le_bytes());
    }
}

/// Append `vals` as their IEEE-754 bit patterns, little-endian (see
/// [`put_u32_slab`]; NaN-bit-exact).
fn put_f32_slab(buf: &mut Vec<u8>, vals: &[f32]) {
    let start = buf.len();
    buf.resize(start + 4 * vals.len(), 0);
    for (dst, v) in buf[start..].chunks_exact_mut(4).zip(vals) {
        dst.copy_from_slice(&v.to_bits().to_le_bytes());
    }
}

/// Bounded cursor over a received payload.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Cursor { buf, pos: 0 }
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Check that `n` more bytes exist without consuming them — used to
    /// reject hostile declared counts *before* any allocation is sized
    /// from them.
    fn require(&self, n: usize, what: &str) -> Result<()> {
        if n > self.remaining() {
            return Err(Error::protocol(format!(
                "declared {what} needs {n} bytes but only {} remain in the frame",
                self.remaining()
            )));
        }
        Ok(())
    }

    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8]> {
        let end = self
            .pos
            .checked_add(n)
            .ok_or_else(|| Error::protocol(format!("length overflow reading {what}")))?;
        if end > self.buf.len() {
            return Err(Error::protocol(format!(
                "truncated frame: need {n} bytes for {what}, have {}",
                self.remaining()
            )));
        }
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self, what: &str) -> Result<u8> {
        Ok(self.take(1, what)?[0])
    }

    fn u32(&mut self, what: &str) -> Result<u32> {
        let b = self.take(4, what)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self, what: &str) -> Result<u64> {
        let b = self.take(8, what)?;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }

    fn f64(&mut self, what: &str) -> Result<f64> {
        Ok(f64::from_bits(self.u64(what)?))
    }

    /// Decode `n` little-endian u32s in one bulk pass. The byte length
    /// is validated by `take` before the output vector is allocated.
    fn u32_slab(&mut self, n: usize, what: &str) -> Result<Vec<u32>> {
        let byte_len = n
            .checked_mul(4)
            .ok_or_else(|| Error::protocol(format!("length overflow reading {what}")))?;
        let bytes = self.take(byte_len, what)?;
        let mut v = Vec::with_capacity(n);
        v.extend(
            bytes
                .chunks_exact(4)
                .map(|b| u32::from_le_bytes([b[0], b[1], b[2], b[3]])),
        );
        Ok(v)
    }

    /// Decode `n` f32 bit patterns in one bulk pass (NaN-bit-exact; see
    /// [`Cursor::u32_slab`] for the validate-before-allocate contract).
    fn f32_slab(&mut self, n: usize, what: &str) -> Result<Vec<f32>> {
        let byte_len = n
            .checked_mul(4)
            .ok_or_else(|| Error::protocol(format!("length overflow reading {what}")))?;
        let bytes = self.take(byte_len, what)?;
        let mut v = Vec::with_capacity(n);
        v.extend(
            bytes
                .chunks_exact(4)
                .map(|b| f32::from_bits(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))),
        );
        Ok(v)
    }

    fn finish(&self, what: &str) -> Result<()> {
        if self.pos != self.buf.len() {
            return Err(Error::protocol(format!(
                "{} trailing bytes after {what}",
                self.remaining()
            )));
        }
        Ok(())
    }
}

fn encode_message(buf: &mut Vec<u8>, msg: &Message) {
    match msg {
        Message::Selection(s) => {
            buf.push(MSG_SELECTION);
            put_u32(buf, s.idx.len() as u32);
            put_u32_slab(buf, &s.idx);
            put_f32_slab(buf, &s.val);
        }
        Message::Floats(v) => {
            buf.push(MSG_FLOATS);
            put_u32(buf, v.len() as u32);
            put_f32_slab(buf, v);
        }
        Message::Scalar(x) => {
            buf.push(MSG_SCALAR);
            put_f64(buf, *x);
        }
        Message::Sparse(s) => {
            buf.push(MSG_SPARSE);
            put_u32(buf, s.idx.len() as u32);
            put_u32_slab(buf, &s.idx);
            put_f32_slab(buf, &s.val);
        }
    }
}

fn decode_message(c: &mut Cursor<'_>) -> Result<Message> {
    match c.u8("message kind")? {
        MSG_SELECTION => {
            let n = c.u32("selection count")? as usize;
            // idx + val slabs: 8 bytes per declared entry, proven
            // present before either vector is allocated
            let total = n
                .checked_mul(8)
                .ok_or_else(|| Error::protocol("selection count overflows"))?;
            c.require(total, "selection payload")?;
            let idx = c.u32_slab(n, "selection indices")?;
            let val = c.f32_slab(n, "selection values")?;
            Ok(Message::Selection(Arc::new(SelectOutput { idx, val })))
        }
        MSG_FLOATS => {
            let n = c.u32("float count")? as usize;
            let total = n
                .checked_mul(4)
                .ok_or_else(|| Error::protocol("float count overflows"))?;
            c.require(total, "float payload")?;
            let v = c.f32_slab(n, "float values")?;
            Ok(Message::Floats(Arc::new(v)))
        }
        MSG_SCALAR => Ok(Message::Scalar(c.f64("scalar")?)),
        MSG_SPARSE => {
            let n = c.u32("sparse count")? as usize;
            // idx + val slabs: 8 bytes per declared entry, proven
            // present before either vector is allocated
            let total = n
                .checked_mul(8)
                .ok_or_else(|| Error::protocol("sparse count overflows"))?;
            c.require(total, "sparse payload")?;
            let idx = c.u32_slab(n, "sparse indices")?;
            let val = c.f32_slab(n, "sparse values")?;
            // positions bound against the round's union at the
            // transport layer, where the union length is known
            check_sparse_idx(&idx, None, "sparse message")?;
            Ok(Message::Sparse(Arc::new(SparseVec { idx, val })))
        }
        other => Err(Error::protocol(format!("unknown message kind {other}"))),
    }
}

/// Encode `frame`'s payload directly into `buf` (no intermediate payload
/// vector); returns the frame kind.
fn encode_payload_into(frame: &Frame, buf: &mut Vec<u8>) -> u8 {
    match frame {
        Frame::Data { generation, msg } => {
            put_u64(buf, *generation);
            encode_message(buf, msg);
            KIND_DATA
        }
        Frame::Hello { world, rank } => {
            put_u32(buf, *world);
            put_u32(buf, *rank);
            KIND_HELLO
        }
        Frame::Welcome { world } => {
            put_u32(buf, *world);
            KIND_WELCOME
        }
        Frame::Reject { reason } => {
            let bytes = reason.as_bytes();
            put_u32(buf, bytes.len() as u32);
            buf.extend_from_slice(bytes);
            KIND_REJECT
        }
        Frame::Abort { rank, generation } => {
            put_u32(buf, *rank);
            put_u64(buf, *generation);
            KIND_ABORT
        }
        Frame::HelloRing { world, rank, port } => {
            put_u32(buf, *world);
            put_u32(buf, *rank);
            put_u16(buf, *port);
            KIND_HELLO_RING
        }
        Frame::WelcomeRing { world, right_addr } => {
            put_u32(buf, *world);
            let bytes = right_addr.as_bytes();
            put_u32(buf, bytes.len() as u32);
            buf.extend_from_slice(bytes);
            KIND_WELCOME_RING
        }
        Frame::RingLink { rank } => {
            put_u32(buf, *rank);
            KIND_RING_LINK
        }
        Frame::Shard {
            generation,
            step,
            chunk,
            vals,
        } => {
            put_u64(buf, *generation);
            put_u32(buf, *step);
            put_u32(buf, *chunk);
            put_u32(buf, vals.len() as u32);
            put_f32_slab(buf, vals);
            KIND_SHARD
        }
        Frame::SparseShard {
            generation,
            step,
            chunk,
            shard_len,
            idx,
            vals,
        } => {
            put_u64(buf, *generation);
            put_u32(buf, *step);
            put_u32(buf, *chunk);
            put_u32(buf, *shard_len);
            put_u32(buf, idx.len() as u32);
            put_u32_slab(buf, idx);
            put_f32_slab(buf, vals);
            KIND_SPARSE_SHARD
        }
        Frame::HelloEpoch {
            epoch,
            orig_rank,
            next_t,
            port,
            standby_port,
        } => {
            put_u64(buf, *epoch);
            put_u32(buf, *orig_rank);
            put_u64(buf, *next_t);
            put_u16(buf, *port);
            put_u16(buf, *standby_port);
            KIND_HELLO_EPOCH
        }
        Frame::HelloJoin {
            orig_rank,
            port,
            standby_port,
        } => {
            put_u32(buf, *orig_rank);
            put_u16(buf, *port);
            put_u16(buf, *standby_port);
            KIND_HELLO_JOIN
        }
        Frame::WelcomeEpoch {
            epoch,
            rank,
            world,
            resume_t,
            right_addr,
            snapshot,
            succession,
        } => {
            put_u64(buf, *epoch);
            put_u32(buf, *rank);
            put_u32(buf, world.len() as u32);
            put_u32_slab(buf, world);
            put_u64(buf, *resume_t);
            let addr = right_addr.as_bytes();
            put_u32(buf, addr.len() as u32);
            buf.extend_from_slice(addr);
            put_u32(buf, snapshot.len() as u32);
            buf.extend_from_slice(snapshot);
            put_u32(buf, succession.len() as u32);
            for entry in succession {
                let bytes = entry.as_bytes();
                put_u32(buf, bytes.len() as u32);
                buf.extend_from_slice(bytes);
            }
            KIND_WELCOME_EPOCH
        }
    }
}

fn decode_payload(kind: u8, payload: &[u8]) -> Result<Frame> {
    let mut c = Cursor::new(payload);
    let frame = match kind {
        KIND_DATA => {
            let generation = c.u64("generation")?;
            let msg = decode_message(&mut c)?;
            Frame::Data { generation, msg }
        }
        KIND_HELLO => Frame::Hello {
            world: c.u32("hello world size")?,
            rank: c.u32("hello rank")?,
        },
        KIND_WELCOME => Frame::Welcome {
            world: c.u32("welcome world size")?,
        },
        KIND_REJECT => {
            let n = c.u32("reject reason length")? as usize;
            let bytes = c.take(n, "reject reason")?;
            let reason = String::from_utf8(bytes.to_vec())
                .map_err(|_| Error::protocol("reject reason is not UTF-8"))?;
            Frame::Reject { reason }
        }
        KIND_ABORT => Frame::Abort {
            rank: c.u32("abort rank")?,
            generation: c.u64("abort generation")?,
        },
        KIND_HELLO_RING => {
            let world = c.u32("hello-ring world size")?;
            let rank = c.u32("hello-ring rank")?;
            let b = c.take(2, "hello-ring port")?;
            Frame::HelloRing {
                world,
                rank,
                port: u16::from_le_bytes([b[0], b[1]]),
            }
        }
        KIND_WELCOME_RING => {
            let world = c.u32("welcome-ring world size")?;
            let n = c.u32("welcome-ring addr length")? as usize;
            let bytes = c.take(n, "welcome-ring addr")?;
            let right_addr = String::from_utf8(bytes.to_vec())
                .map_err(|_| Error::protocol("welcome-ring addr is not UTF-8"))?;
            Frame::WelcomeRing { world, right_addr }
        }
        KIND_RING_LINK => Frame::RingLink {
            rank: c.u32("ring-link rank")?,
        },
        KIND_SHARD => {
            let generation = c.u64("shard generation")?;
            let step = c.u32("shard step")?;
            let chunk = c.u32("shard chunk")?;
            let n = c.u32("shard count")? as usize;
            let total = n
                .checked_mul(4)
                .ok_or_else(|| Error::protocol("shard count overflows"))?;
            c.require(total, "shard payload")?;
            let vals = c.f32_slab(n, "shard values")?;
            Frame::Shard {
                generation,
                step,
                chunk,
                vals,
            }
        }
        KIND_SPARSE_SHARD => {
            let generation = c.u64("sparse-shard generation")?;
            let step = c.u32("sparse-shard step")?;
            let chunk = c.u32("sparse-shard chunk")?;
            let shard_len = c.u32("sparse-shard length")?;
            let n = c.u32("sparse-shard count")? as usize;
            let total = n
                .checked_mul(8)
                .ok_or_else(|| Error::protocol("sparse-shard count overflows"))?;
            c.require(total, "sparse-shard payload")?;
            let idx = c.u32_slab(n, "sparse-shard indices")?;
            let vals = c.f32_slab(n, "sparse-shard values")?;
            check_sparse_idx(&idx, Some(shard_len), "sparse-shard")?;
            Frame::SparseShard {
                generation,
                step,
                chunk,
                shard_len,
                idx,
                vals,
            }
        }
        KIND_HELLO_EPOCH => {
            let epoch = c.u64("hello-epoch epoch")?;
            let orig_rank = c.u32("hello-epoch rank")?;
            let next_t = c.u64("hello-epoch next_t")?;
            let b = c.take(2, "hello-epoch port")?;
            let port = u16::from_le_bytes([b[0], b[1]]);
            let s = c.take(2, "hello-epoch standby port")?;
            Frame::HelloEpoch {
                epoch,
                orig_rank,
                next_t,
                port,
                standby_port: u16::from_le_bytes([s[0], s[1]]),
            }
        }
        KIND_HELLO_JOIN => {
            let orig_rank = c.u32("hello-join rank")?;
            let b = c.take(2, "hello-join port")?;
            let port = u16::from_le_bytes([b[0], b[1]]);
            let s = c.take(2, "hello-join standby port")?;
            Frame::HelloJoin {
                orig_rank,
                port,
                standby_port: u16::from_le_bytes([s[0], s[1]]),
            }
        }
        KIND_WELCOME_EPOCH => {
            let epoch = c.u64("welcome-epoch epoch")?;
            let rank = c.u32("welcome-epoch rank")?;
            let n = c.u32("welcome-epoch world size")? as usize;
            let total = n
                .checked_mul(4)
                .ok_or_else(|| Error::protocol("welcome-epoch world size overflows"))?;
            c.require(total, "welcome-epoch world")?;
            let world = c.u32_slab(n, "welcome-epoch world")?;
            let resume_t = c.u64("welcome-epoch resume_t")?;
            let alen = c.u32("welcome-epoch addr length")? as usize;
            let abytes = c.take(alen, "welcome-epoch addr")?;
            let right_addr = String::from_utf8(abytes.to_vec())
                .map_err(|_| Error::protocol("welcome-epoch addr is not UTF-8"))?;
            let slen = c.u32("welcome-epoch snapshot length")? as usize;
            let snapshot = c.take(slen, "welcome-epoch snapshot")?.to_vec();
            let sn = c.u32("welcome-epoch succession size")? as usize;
            // each entry needs at least its 4-byte length prefix, so a
            // corrupt count is rejected before the Vec is sized from it
            c.require(
                sn.checked_mul(4)
                    .ok_or_else(|| Error::protocol("welcome-epoch succession size overflows"))?,
                "welcome-epoch succession",
            )?;
            let mut succession = Vec::with_capacity(sn);
            for _ in 0..sn {
                let elen = c.u32("welcome-epoch succession entry length")? as usize;
                let ebytes = c.take(elen, "welcome-epoch succession entry")?;
                succession.push(
                    String::from_utf8(ebytes.to_vec()).map_err(|_| {
                        Error::protocol("welcome-epoch succession entry is not UTF-8")
                    })?,
                );
            }
            Frame::WelcomeEpoch {
                epoch,
                rank,
                world,
                resume_t,
                right_addr,
                snapshot,
                succession,
            }
        }
        other => return Err(Error::protocol(format!("unknown frame kind {other}"))),
    };
    c.finish("frame payload")?;
    Ok(frame)
}

/// Append one frame's complete wire bytes to `buf` — the reusable-buffer
/// form: the hub encodes a whole board into one persistent buffer and
/// fans the identical byte run out to every peer.
pub fn encode_frame_append(frame: &Frame, buf: &mut Vec<u8>) {
    let frame_start = buf.len();
    put_u32(buf, MAGIC);
    put_u16(buf, PROTOCOL_VERSION);
    buf.push(0); // kind, patched below
    put_u32(buf, 0); // payload length, patched below
    let body_start = buf.len();
    let kind = encode_payload_into(frame, buf);
    let len = (buf.len() - body_start) as u32;
    buf[frame_start + 6] = kind;
    buf[frame_start + 7..frame_start + 11].copy_from_slice(&len.to_le_bytes());
    let check = fnv1a(&buf[frame_start..]);
    put_u32(buf, check);
}

/// Encode one frame to its complete wire bytes (allocating wrapper over
/// [`encode_frame_append`]).
pub fn encode_frame(frame: &Frame) -> Vec<u8> {
    let mut buf = Vec::new();
    encode_frame_append(frame, &mut buf);
    buf
}

/// Append one [`Frame::Shard`]'s complete wire bytes straight from a
/// value slice — byte-identical to `encode_frame_append` on the
/// equivalent `Frame::Shard`, without building the frame (the ring
/// transport's reduce-scatter hot path encodes chunk ranges of stashed
/// contributions and accumulator buffers without a `Vec` per hop).
pub fn encode_shard_append(
    buf: &mut Vec<u8>,
    generation: u64,
    step: u32,
    chunk: u32,
    vals: &[f32],
) {
    let frame_start = buf.len();
    put_u32(buf, MAGIC);
    put_u16(buf, PROTOCOL_VERSION);
    buf.push(KIND_SHARD);
    put_u32(buf, 0); // payload length, patched below
    let body_start = buf.len();
    put_u64(buf, generation);
    put_u32(buf, step);
    put_u32(buf, chunk);
    put_u32(buf, vals.len() as u32);
    put_f32_slab(buf, vals);
    let len = (buf.len() - body_start) as u32;
    buf[frame_start + 7..frame_start + 11].copy_from_slice(&len.to_le_bytes());
    let check = fnv1a(&buf[frame_start..]);
    put_u32(buf, check);
}

/// Append one [`Frame::SparseShard`]'s complete wire bytes straight
/// from `(idx, vals)` slices — byte-identical to `encode_frame_append`
/// on the equivalent frame, without building it (the ring transport's
/// sparse reduce-scatter hot path encodes partial entry lists out of
/// reusable buffers without a `Vec` per hop). `idx` is shard-local.
pub fn encode_sparse_shard_append(
    buf: &mut Vec<u8>,
    generation: u64,
    step: u32,
    chunk: u32,
    shard_len: u32,
    idx: &[u32],
    vals: &[f32],
) {
    debug_assert_eq!(idx.len(), vals.len());
    let frame_start = buf.len();
    put_u32(buf, MAGIC);
    put_u16(buf, PROTOCOL_VERSION);
    buf.push(KIND_SPARSE_SHARD);
    put_u32(buf, 0); // payload length, patched below
    let body_start = buf.len();
    put_u64(buf, generation);
    put_u32(buf, step);
    put_u32(buf, chunk);
    put_u32(buf, shard_len);
    put_u32(buf, idx.len() as u32);
    put_u32_slab(buf, idx);
    put_f32_slab(buf, vals);
    let len = (buf.len() - body_start) as u32;
    buf[frame_start + 7..frame_start + 11].copy_from_slice(&len.to_le_bytes());
    let check = fnv1a(&buf[frame_start..]);
    put_u32(buf, check);
}

fn parse_header(h: &[u8; HEADER_LEN]) -> Result<(u8, u32)> {
    let magic = u32::from_le_bytes([h[0], h[1], h[2], h[3]]);
    if magic != MAGIC {
        return Err(Error::protocol(format!(
            "bad frame magic {magic:#010x} (want {MAGIC:#010x})"
        )));
    }
    let version = u16::from_le_bytes([h[4], h[5]]);
    if version != PROTOCOL_VERSION {
        return Err(Error::protocol(format!(
            "protocol version mismatch: peer speaks v{version}, this build speaks v{PROTOCOL_VERSION}"
        )));
    }
    let kind = h[6];
    let len = u32::from_le_bytes([h[7], h[8], h[9], h[10]]);
    if len > MAX_PAYLOAD {
        return Err(Error::protocol(format!(
            "frame payload length {len} exceeds cap {MAX_PAYLOAD}"
        )));
    }
    Ok((kind, len))
}

/// Decode one frame from a complete in-memory buffer (must contain
/// exactly one frame).
pub fn decode_frame(bytes: &[u8]) -> Result<Frame> {
    if bytes.len() < HEADER_LEN + 4 {
        return Err(Error::protocol(format!(
            "truncated frame: {} bytes, need at least {}",
            bytes.len(),
            HEADER_LEN + 4
        )));
    }
    let mut header = [0u8; HEADER_LEN];
    header.copy_from_slice(&bytes[..HEADER_LEN]);
    let (kind, len) = parse_header(&header)?;
    let want = HEADER_LEN + len as usize + 4;
    if bytes.len() != want {
        return Err(Error::protocol(format!(
            "frame length mismatch: buffer has {} bytes, header says {want}",
            bytes.len()
        )));
    }
    let body_end = HEADER_LEN + len as usize;
    let stored = u32::from_le_bytes([
        bytes[body_end],
        bytes[body_end + 1],
        bytes[body_end + 2],
        bytes[body_end + 3],
    ]);
    let computed = fnv1a(&bytes[..body_end]);
    if stored != computed {
        return Err(Error::protocol(format!(
            "frame checksum mismatch: stored {stored:#010x}, computed {computed:#010x}"
        )));
    }
    decode_payload(kind, &bytes[HEADER_LEN..body_end])
}

fn map_read_err(e: std::io::Error, what: &str) -> Error {
    match e.kind() {
        std::io::ErrorKind::UnexpectedEof => {
            Error::protocol(format!("peer closed connection mid-frame ({what})"))
        }
        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut => {
            Error::net(format!("read timed out waiting for {what}"))
        }
        _ => Error::Io(e),
    }
}

/// Read one frame from a stream through a caller-owned scratch buffer
/// (grown to the high-water frame size and reused, so a steady-state
/// peer neither allocates nor re-zeroes per frame). Timeouts surface as
/// [`Error::Net`], a clean close before the first header byte as a
/// distinguishable "connection closed" protocol error.
pub fn read_frame_with(r: &mut impl Read, scratch: &mut Vec<u8>) -> Result<Frame> {
    read_frame_counted(r, scratch).map(|(frame, _)| frame)
}

/// Like [`read_frame_with`], but also report the gross wire bytes the
/// frame occupied on the stream (header + payload + checksum) — what
/// the obs wire-byte counters bump by, measured at the exact boundary
/// the bytes crossed.
pub fn read_frame_counted(r: &mut impl Read, scratch: &mut Vec<u8>) -> Result<(Frame, usize)> {
    let mut header = [0u8; HEADER_LEN];
    // distinguish a clean close (0 bytes) from a mid-frame cut
    let mut got = 0usize;
    while got < HEADER_LEN {
        match r.read(&mut header[got..]) {
            Ok(0) => {
                return Err(if got == 0 {
                    Error::protocol("connection closed by peer")
                } else {
                    Error::protocol("peer closed connection mid-frame (header)")
                });
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(map_read_err(e, "frame header")),
        }
    }
    let (kind, len) = parse_header(&header)?;
    let body_end = len as usize;
    let need = body_end + 4;
    if scratch.len() < need {
        // grow once to the high-water mark; no per-frame re-zeroing of
        // bytes read_exact is about to overwrite anyway
        scratch.resize(need, 0);
    }
    let frame_buf = &mut scratch[..need];
    r.read_exact(frame_buf)
        .map_err(|e| map_read_err(e, "frame body"))?;
    let stored = u32::from_le_bytes([
        frame_buf[body_end],
        frame_buf[body_end + 1],
        frame_buf[body_end + 2],
        frame_buf[body_end + 3],
    ]);
    let computed = fnv1a_update(fnv1a_update(FNV_SEED, &header), &frame_buf[..body_end]);
    if stored != computed {
        return Err(Error::protocol(format!(
            "frame checksum mismatch: stored {stored:#010x}, computed {computed:#010x}"
        )));
    }
    let frame = decode_payload(kind, &frame_buf[..body_end])?;
    Ok((frame, HEADER_LEN + need))
}

/// Read one frame from a stream (allocating wrapper over
/// [`read_frame_with`]).
pub fn read_frame(r: &mut impl Read) -> Result<Frame> {
    let mut scratch = Vec::new();
    read_frame_with(r, &mut scratch)
}

/// Write one frame to a stream. Timeouts surface as [`Error::Net`].
pub fn write_frame(w: &mut impl Write, frame: &Frame) -> Result<()> {
    write_bytes(w, &encode_frame(frame))
}

/// Write pre-encoded frame bytes (lets the hub encode a board once and
/// fan the same bytes out to every peer).
pub fn write_bytes(w: &mut impl Write, bytes: &[u8]) -> Result<()> {
    w.write_all(bytes).map_err(|e| match e.kind() {
        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut => {
            Error::net("write timed out")
        }
        _ => Error::Io(e),
    })?;
    w.flush().map_err(Error::Io)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, Strategy};
    use crate::util::rng::Rng;

    /// Random frames, biased toward Data payloads; injects NaN/Inf bit
    /// patterns and empty selections.
    struct FrameStrat;

    fn gen_f32(rng: &mut Rng) -> f32 {
        match rng.usize(5) {
            0 => f32::NAN,
            1 => f32::from_bits(0x7FC0_1234), // payload-carrying NaN
            2 => f32::INFINITY,
            _ => (rng.f32() - 0.5) * 1e6,
        }
    }

    /// `n` strictly increasing positions with random gaps — the only
    /// index shape the sparse decoders accept.
    fn gen_sparse_idx(rng: &mut Rng, n: usize) -> Vec<u32> {
        let mut idx = Vec::with_capacity(n);
        let mut next = 0u32;
        for _ in 0..n {
            next += rng.usize(3) as u32;
            idx.push(next);
            next += 1;
        }
        idx
    }

    fn gen_message(rng: &mut Rng) -> Message {
        match rng.usize(4) {
            0 => {
                let n = rng.usize(40); // 0 => empty selection
                let idx: Vec<u32> = (0..n).map(|_| rng.next_u64() as u32).collect();
                let val: Vec<f32> = (0..n).map(|_| gen_f32(rng)).collect();
                Message::Selection(Arc::new(SelectOutput { idx, val }))
            }
            1 => {
                let n = rng.usize(40);
                Message::Floats(Arc::new((0..n).map(|_| gen_f32(rng)).collect()))
            }
            2 => {
                let n = rng.usize(40); // 0 => empty entry list
                let idx = gen_sparse_idx(rng, n);
                let val: Vec<f32> = (0..n).map(|_| gen_f32(rng)).collect();
                Message::Sparse(Arc::new(SparseVec { idx, val }))
            }
            _ => Message::Scalar(if rng.usize(4) == 0 {
                f64::NAN
            } else {
                rng.f64() * 1e9
            }),
        }
    }

    impl Strategy for FrameStrat {
        type Value = Frame;
        fn gen(&self, rng: &mut Rng) -> Frame {
            match rng.usize(14) {
                0 | 1 => Frame::Data {
                    generation: rng.next_u64(),
                    msg: gen_message(rng),
                },
                10 => Frame::HelloEpoch {
                    epoch: rng.next_u64(),
                    orig_rank: rng.usize(64) as u32,
                    next_t: rng.next_u64(),
                    port: rng.next_u64() as u16,
                    standby_port: rng.next_u64() as u16,
                },
                11 => Frame::HelloJoin {
                    orig_rank: rng.usize(64) as u32,
                    port: rng.next_u64() as u16,
                    standby_port: rng.next_u64() as u16,
                },
                12 => Frame::WelcomeEpoch {
                    epoch: rng.next_u64(),
                    rank: rng.usize(64) as u32,
                    world: (0..rng.usize(8)).map(|r| r as u32).collect(),
                    resume_t: rng.next_u64(),
                    right_addr: if rng.usize(2) == 0 {
                        String::new()
                    } else {
                        format!("127.0.0.1:{}", rng.next_u64() as u16)
                    },
                    snapshot: (0..rng.usize(32)).map(|_| rng.next_u64() as u8).collect(),
                    succession: (0..rng.usize(6))
                        .map(|_| {
                            if rng.usize(4) == 0 {
                                String::new()
                            } else {
                                format!("127.0.0.1:{}", rng.next_u64() as u16)
                            }
                        })
                        .collect(),
                },
                8 => Frame::Shard {
                    generation: rng.next_u64(),
                    step: rng.usize(16) as u32,
                    chunk: rng.usize(16) as u32,
                    vals: (0..rng.usize(40)).map(|_| gen_f32(rng)).collect(),
                },
                9 => {
                    let n = rng.usize(40);
                    let idx = gen_sparse_idx(rng, n);
                    let shard_len = idx.last().map_or(0, |&l| l + 1) + rng.usize(8) as u32;
                    Frame::SparseShard {
                        generation: rng.next_u64(),
                        step: rng.usize(16) as u32,
                        chunk: rng.usize(16) as u32,
                        shard_len,
                        idx,
                        vals: (0..n).map(|_| gen_f32(rng)).collect(),
                    }
                }
                2 => Frame::Hello {
                    world: rng.usize(64) as u32,
                    rank: rng.usize(64) as u32,
                },
                3 => Frame::Welcome {
                    world: rng.usize(64) as u32,
                },
                4 => Frame::Reject {
                    reason: format!("reason-{}", rng.usize(1000)),
                },
                5 => Frame::HelloRing {
                    world: rng.usize(64) as u32,
                    rank: rng.usize(64) as u32,
                    port: rng.next_u64() as u16,
                },
                6 => Frame::WelcomeRing {
                    world: rng.usize(64) as u32,
                    right_addr: format!("127.0.0.1:{}", rng.next_u64() as u16),
                },
                7 => Frame::RingLink {
                    rank: rng.usize(64) as u32,
                },
                _ => Frame::Abort {
                    rank: if rng.usize(3) == 0 {
                        ABORT_RANK_UNKNOWN
                    } else {
                        rng.usize(64) as u32
                    },
                    generation: rng.next_u64(),
                },
            }
        }
    }

    /// Canonical-bytes round trip: re-encoding the decoded frame must
    /// reproduce the original bytes exactly, which proves bit-exact
    /// payload round-trips even for NaN (where `PartialEq` can't).
    #[test]
    fn roundtrip_property_all_variants() {
        check(0xC0DEC, 400, &FrameStrat, |frame| {
            let bytes = encode_frame(frame);
            let decoded = decode_frame(&bytes)
                .map_err(|e| format!("decode failed: {e} for {frame:?}"))?;
            let re = encode_frame(&decoded);
            if re != bytes {
                return Err(format!("re-encode differs for {frame:?}"));
            }
            // streaming path agrees with the in-memory path
            let mut cursor: &[u8] = &bytes;
            let streamed =
                read_frame(&mut cursor).map_err(|e| format!("read_frame failed: {e}"))?;
            if encode_frame(&streamed) != bytes {
                return Err(format!("read_frame round trip differs for {frame:?}"));
            }
            // appending into a dirty reusable buffer yields the same bytes
            let mut appended = vec![0xA5u8; 7];
            encode_frame_append(frame, &mut appended);
            if appended[7..] != bytes[..] {
                return Err(format!("encode_frame_append differs for {frame:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn empty_selection_roundtrips() {
        let f = Frame::Data {
            generation: 7,
            msg: Message::Selection(Arc::new(SelectOutput::default())),
        };
        let bytes = encode_frame(&f);
        assert_eq!(decode_frame(&bytes).unwrap(), f);
    }

    #[test]
    fn nan_floats_roundtrip_bit_exactly() {
        let vals = vec![f32::NAN, f32::from_bits(0x7FC0_0001), -0.0, f32::INFINITY];
        let bits: Vec<u32> = vals.iter().map(|v| v.to_bits()).collect();
        let f = Frame::Data {
            generation: 1,
            msg: Message::Floats(Arc::new(vals)),
        };
        match decode_frame(&encode_frame(&f)).unwrap() {
            Frame::Data {
                msg: Message::Floats(got),
                ..
            } => {
                let got_bits: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
                assert_eq!(got_bits, bits);
            }
            other => panic!("wrong frame {other:?}"),
        }
        let s = Frame::Data {
            generation: 2,
            msg: Message::Scalar(f64::NAN),
        };
        match decode_frame(&encode_frame(&s)).unwrap() {
            Frame::Data {
                msg: Message::Scalar(x),
                ..
            } => assert_eq!(x.to_bits(), f64::NAN.to_bits()),
            other => panic!("wrong frame {other:?}"),
        }
    }

    #[test]
    fn every_truncation_is_rejected_not_panicking() {
        let f = Frame::Data {
            generation: 42,
            msg: Message::Selection(Arc::new(SelectOutput {
                idx: vec![3, 9, 11],
                val: vec![1.0, -2.0, f32::NAN],
            })),
        };
        let bytes = encode_frame(&f);
        for k in 0..bytes.len() {
            assert!(
                decode_frame(&bytes[..k]).is_err(),
                "prefix of {k} bytes must be rejected"
            );
            let mut cursor = &bytes[..k];
            assert!(
                read_frame(&mut cursor).is_err(),
                "streamed prefix of {k} bytes must be rejected"
            );
        }
    }

    #[test]
    fn every_single_byte_flip_is_rejected() {
        let f = Frame::Data {
            generation: 3,
            msg: Message::Floats(Arc::new(vec![1.5, -2.5, 0.0])),
        };
        let bytes = encode_frame(&f);
        for pos in 0..bytes.len() {
            for flip in [0x01u8, 0x80] {
                let mut c = bytes.clone();
                c[pos] ^= flip;
                assert!(
                    decode_frame(&c).is_err(),
                    "flip {flip:#x} at byte {pos} must be rejected"
                );
            }
        }
    }

    #[test]
    fn oversized_length_rejected_before_allocation() {
        // hand-build a header claiming a huge payload
        let mut h = Vec::new();
        put_u32(&mut h, MAGIC);
        put_u16(&mut h, PROTOCOL_VERSION);
        h.push(0);
        put_u32(&mut h, u32::MAX);
        h.extend_from_slice(&[0u8; 16]);
        let err = decode_frame(&h).unwrap_err().to_string();
        assert!(err.contains("exceeds cap"), "{err}");
    }

    /// A hostile frame with a valid header and checksum whose *declared
    /// element count* promises far more data than the frame carries must
    /// be rejected up front — before any buffer is sized from the count.
    #[test]
    fn hostile_declared_count_rejected_before_allocation() {
        // Floats message claiming 50M entries (~200 MB) with an empty body
        let mut payload = Vec::new();
        put_u64(&mut payload, 0); // generation
        payload.push(MSG_FLOATS);
        put_u32(&mut payload, 50_000_000);
        let mut frame = Vec::new();
        put_u32(&mut frame, MAGIC);
        put_u16(&mut frame, PROTOCOL_VERSION);
        frame.push(KIND_DATA);
        put_u32(&mut frame, payload.len() as u32);
        frame.extend_from_slice(&payload);
        let check = fnv1a(&frame);
        put_u32(&mut frame, check);
        let err = decode_frame(&frame).unwrap_err();
        assert!(matches!(err, Error::Protocol(_)), "{err}");
        assert!(err.to_string().contains("remain"), "{err}");

        // Selection variant: count covers the idx slab but not the vals —
        // still rejected before the idx vector would be allocated
        let mut payload = Vec::new();
        put_u64(&mut payload, 0);
        payload.push(MSG_SELECTION);
        put_u32(&mut payload, 1000);
        payload.extend_from_slice(&vec![0u8; 4000]); // idx bytes only
        let mut frame = Vec::new();
        put_u32(&mut frame, MAGIC);
        put_u16(&mut frame, PROTOCOL_VERSION);
        frame.push(KIND_DATA);
        put_u32(&mut frame, payload.len() as u32);
        frame.extend_from_slice(&payload);
        let check = fnv1a(&frame);
        put_u32(&mut frame, check);
        let err = decode_frame(&frame).unwrap_err();
        assert!(matches!(err, Error::Protocol(_)), "{err}");
        assert!(err.to_string().contains("selection payload"), "{err}");
    }

    #[test]
    fn version_and_magic_mismatches_are_typed() {
        let good = encode_frame(&Frame::Abort {
            rank: 1,
            generation: 0,
        });
        let mut bad_magic = good.clone();
        bad_magic[0] ^= 0xFF;
        let e = decode_frame(&bad_magic).unwrap_err().to_string();
        assert!(e.contains("magic") || e.contains("checksum"), "{e}");

        let mut bad_version = good.clone();
        bad_version[4] = 99;
        let e = decode_frame(&bad_version).unwrap_err().to_string();
        assert!(e.contains("version"), "{e}");
    }

    #[test]
    fn clean_close_is_distinguishable() {
        let mut empty: &[u8] = &[];
        let e = read_frame(&mut empty).unwrap_err().to_string();
        assert!(e.contains("connection closed by peer"), "{e}");
    }

    #[test]
    fn ring_rendezvous_frames_roundtrip() {
        for f in [
            Frame::HelloRing {
                world: 4,
                rank: 3,
                port: 61_234,
            },
            Frame::WelcomeRing {
                world: 4,
                right_addr: "10.0.0.7:29500".to_string(),
            },
            Frame::RingLink { rank: 2 },
        ] {
            let bytes = encode_frame(&f);
            assert_eq!(decode_frame(&bytes).unwrap(), f);
            for k in 0..bytes.len() {
                assert!(
                    decode_frame(&bytes[..k]).is_err(),
                    "truncated ring frame at {k} must be rejected"
                );
            }
        }
    }

    #[test]
    fn shard_frames_roundtrip_and_match_the_slice_encoder() {
        let vals = vec![1.5f32, f32::from_bits(0x7FC0_1234), -0.0, 3.25];
        let f = Frame::Shard {
            generation: 9,
            step: 2,
            chunk: 1,
            vals: vals.clone(),
        };
        let bytes = encode_frame(&f);
        // canonical-bytes round trip (PartialEq can't see through NaN)
        let decoded = decode_frame(&bytes).unwrap();
        assert_eq!(encode_frame(&decoded), bytes);
        match decoded {
            Frame::Shard {
                generation,
                step,
                chunk,
                vals: got,
            } => {
                assert_eq!((generation, step, chunk), (9, 2, 1));
                let got: Vec<u32> = got.iter().map(|x| x.to_bits()).collect();
                let want: Vec<u32> = vals.iter().map(|x| x.to_bits()).collect();
                assert_eq!(got, want, "NaN payload bits must survive");
            }
            other => panic!("wrong frame {other:?}"),
        }
        // the slice encoder is byte-identical — it IS the ring hot path
        let mut direct = vec![0x5Au8; 3]; // dirty reusable buffer
        encode_shard_append(&mut direct, 9, 2, 1, &vals);
        assert_eq!(&direct[3..], &bytes[..]);
        for k in 0..bytes.len() {
            assert!(
                decode_frame(&bytes[..k]).is_err(),
                "truncated shard frame at {k} must be rejected"
            );
        }
    }

    #[test]
    fn hostile_shard_count_rejected_before_allocation() {
        // Shard claiming 50M values (~200 MB) with an empty body
        let mut payload = Vec::new();
        put_u64(&mut payload, 0); // generation
        put_u32(&mut payload, 0); // step
        put_u32(&mut payload, 0); // chunk
        put_u32(&mut payload, 50_000_000);
        let mut frame = Vec::new();
        put_u32(&mut frame, MAGIC);
        put_u16(&mut frame, PROTOCOL_VERSION);
        frame.push(KIND_SHARD);
        put_u32(&mut frame, payload.len() as u32);
        frame.extend_from_slice(&payload);
        let check = fnv1a(&frame);
        put_u32(&mut frame, check);
        let err = decode_frame(&frame).unwrap_err();
        assert!(matches!(err, Error::Protocol(_)), "{err}");
        assert!(err.to_string().contains("remain"), "{err}");
    }

    #[test]
    fn sparse_shard_frames_roundtrip_and_match_the_slice_encoder() {
        let idx = vec![0u32, 3, 4, 9];
        let vals = vec![1.5f32, f32::from_bits(0x7FC0_1234), -0.0, 3.25];
        let f = Frame::SparseShard {
            generation: 9,
            step: 2,
            chunk: 1,
            shard_len: 10,
            idx: idx.clone(),
            vals: vals.clone(),
        };
        let bytes = encode_frame(&f);
        // canonical-bytes round trip (PartialEq can't see through NaN)
        let decoded = decode_frame(&bytes).unwrap();
        assert_eq!(encode_frame(&decoded), bytes);
        match decoded {
            Frame::SparseShard {
                generation,
                step,
                chunk,
                shard_len,
                idx: gi,
                vals: gv,
            } => {
                assert_eq!((generation, step, chunk, shard_len), (9, 2, 1, 10));
                assert_eq!(gi, idx);
                let gv: Vec<u32> = gv.iter().map(|x| x.to_bits()).collect();
                let want: Vec<u32> = vals.iter().map(|x| x.to_bits()).collect();
                assert_eq!(gv, want, "NaN payload bits must survive");
            }
            other => panic!("wrong frame {other:?}"),
        }
        // the slice encoder is byte-identical — it IS the ring hot path
        let mut direct = vec![0x5Au8; 3]; // dirty reusable buffer
        encode_sparse_shard_append(&mut direct, 9, 2, 1, 10, &idx, &vals);
        assert_eq!(&direct[3..], &bytes[..]);
        for k in 0..bytes.len() {
            assert!(
                decode_frame(&bytes[..k]).is_err(),
                "truncated sparse-shard frame at {k} must be rejected"
            );
        }
        // an empty entry list is a legal hop (a rank with nothing
        // selected in this shard still forwards)
        let empty = Frame::SparseShard {
            generation: 1,
            step: 0,
            chunk: 0,
            shard_len: 5,
            idx: vec![],
            vals: vec![],
        };
        assert_eq!(decode_frame(&encode_frame(&empty)).unwrap(), empty);
    }

    /// Hand-build a checksummed sparse-shard frame from a raw payload —
    /// the only way to get hostile indices past the FNV check and into
    /// the index validator.
    fn sparse_shard_frame_from_payload(payload: &[u8]) -> Vec<u8> {
        let mut frame = Vec::new();
        put_u32(&mut frame, MAGIC);
        put_u16(&mut frame, PROTOCOL_VERSION);
        frame.push(KIND_SPARSE_SHARD);
        put_u32(&mut frame, payload.len() as u32);
        frame.extend_from_slice(payload);
        let check = fnv1a(&frame);
        put_u32(&mut frame, check);
        frame
    }

    #[test]
    fn hostile_sparse_shard_count_rejected_before_allocation() {
        // claiming 50M entries (~400 MB) with an empty body
        let mut payload = Vec::new();
        put_u64(&mut payload, 0); // generation
        put_u32(&mut payload, 0); // step
        put_u32(&mut payload, 0); // chunk
        put_u32(&mut payload, 100); // shard_len
        put_u32(&mut payload, 50_000_000);
        let err = decode_frame(&sparse_shard_frame_from_payload(&payload)).unwrap_err();
        assert!(matches!(err, Error::Protocol(_)), "{err}");
        assert!(err.to_string().contains("remain"), "{err}");
    }

    #[test]
    fn non_increasing_and_out_of_bounds_sparse_indices_rejected() {
        let build = |idx: &[u32], shard_len: u32| {
            let mut payload = Vec::new();
            put_u64(&mut payload, 7); // generation
            put_u32(&mut payload, 1); // step
            put_u32(&mut payload, 2); // chunk
            put_u32(&mut payload, shard_len);
            put_u32(&mut payload, idx.len() as u32);
            put_u32_slab(&mut payload, idx);
            put_f32_slab(&mut payload, &vec![1.0f32; idx.len()]);
            sparse_shard_frame_from_payload(&payload)
        };
        // out of order
        let err = decode_frame(&build(&[0, 5, 3], 10)).unwrap_err();
        assert!(matches!(err, Error::Protocol(_)), "{err}");
        assert!(err.to_string().contains("strictly increasing"), "{err}");
        // duplicate
        let err = decode_frame(&build(&[0, 3, 3], 10)).unwrap_err();
        assert!(err.to_string().contains("strictly increasing"), "{err}");
        // past the declared shard length (== and >)
        for bad in [10u32, 11, 1_000_000] {
            let err = decode_frame(&build(&[0, 3, bad], 10)).unwrap_err();
            assert!(matches!(err, Error::Protocol(_)), "{err}");
            assert!(err.to_string().contains("out of shard bounds"), "{err}");
        }
        // the boundary cases stay legal
        assert!(decode_frame(&build(&[0, 3, 9], 10)).is_ok());
        assert!(decode_frame(&build(&[], 0)).is_ok());
        // a sparse *message* with unsorted positions is equally typed
        let mut payload = Vec::new();
        put_u64(&mut payload, 0); // generation
        payload.push(MSG_SPARSE);
        put_u32(&mut payload, 2);
        put_u32_slab(&mut payload, &[4, 4]);
        put_f32_slab(&mut payload, &[1.0, 2.0]);
        let mut frame = Vec::new();
        put_u32(&mut frame, MAGIC);
        put_u16(&mut frame, PROTOCOL_VERSION);
        frame.push(KIND_DATA);
        put_u32(&mut frame, payload.len() as u32);
        frame.extend_from_slice(&payload);
        let check = fnv1a(&frame);
        put_u32(&mut frame, check);
        let err = decode_frame(&frame).unwrap_err();
        assert!(matches!(err, Error::Protocol(_)), "{err}");
        assert!(err.to_string().contains("strictly increasing"), "{err}");
    }

    #[test]
    fn every_single_byte_flip_on_a_sparse_shard_is_rejected() {
        let f = Frame::SparseShard {
            generation: 3,
            step: 1,
            chunk: 0,
            shard_len: 8,
            idx: vec![1, 4, 6],
            vals: vec![1.5, -2.5, 0.0],
        };
        let bytes = encode_frame(&f);
        for pos in 0..bytes.len() {
            for flip in [0x01u8, 0x80] {
                let mut c = bytes.clone();
                c[pos] ^= flip;
                assert!(
                    decode_frame(&c).is_err(),
                    "flip {flip:#x} at byte {pos} must be rejected"
                );
            }
        }
    }

    #[test]
    fn two_frames_stream_back_to_back_through_one_scratch_buffer() {
        let a = Frame::Hello { world: 4, rank: 2 };
        let b = Frame::Welcome { world: 4 };
        let mut buf = encode_frame(&a);
        buf.extend_from_slice(&encode_frame(&b));
        let mut cursor: &[u8] = &buf;
        let mut scratch = vec![0xFFu8; 3]; // dirty reusable buffer
        assert_eq!(read_frame_with(&mut cursor, &mut scratch).unwrap(), a);
        assert_eq!(read_frame_with(&mut cursor, &mut scratch).unwrap(), b);
        assert!(read_frame_with(&mut cursor, &mut scratch).is_err());
    }

    #[test]
    fn counted_read_reports_the_exact_wire_bytes() {
        let f = Frame::Data {
            generation: 5,
            msg: Message::Floats(Arc::new(vec![1.0f32; 7])),
        };
        let bytes = encode_frame(&f);
        let mut cursor: &[u8] = &bytes;
        let mut scratch = Vec::new();
        let (got, gross) = read_frame_counted(&mut cursor, &mut scratch).unwrap();
        assert_eq!(got, f);
        assert_eq!(gross, bytes.len(), "gross = header + payload + checksum");
        assert!(
            gross > f.payload_bytes(),
            "framing overhead is real — gross wire bytes strictly exceed payload"
        );
    }

    #[test]
    fn frame_payload_bytes_are_model_units() {
        let data = Frame::Data {
            generation: 0,
            msg: Message::Selection(Arc::new(SelectOutput {
                idx: vec![1, 2],
                val: vec![0.0; 2],
            })),
        };
        assert_eq!(data.payload_bytes(), 2 * 8);
        let shard = Frame::Shard {
            generation: 0,
            step: 0,
            chunk: 0,
            vals: vec![0.0; 6],
        };
        assert_eq!(shard.payload_bytes(), 6 * 4);
        let sparse = Frame::SparseShard {
            generation: 0,
            step: 0,
            chunk: 0,
            shard_len: 16,
            idx: vec![0, 5, 9],
            vals: vec![0.0; 3],
        };
        assert_eq!(sparse.payload_bytes(), 3 * 8);
        let sparse_msg = Frame::Data {
            generation: 0,
            msg: Message::Sparse(Arc::new(SparseVec {
                idx: vec![2, 7],
                val: vec![0.0; 2],
            })),
        };
        assert_eq!(sparse_msg.payload_bytes(), 2 * 8);
        assert_eq!(
            Frame::Abort {
                rank: 0,
                generation: 3
            }
            .payload_bytes(),
            0,
            "control frames carry none"
        );
        assert_eq!(Frame::Hello { world: 2, rank: 1 }.payload_bytes(), 0);
    }

    #[test]
    fn epoch_rendezvous_frames_roundtrip() {
        for f in [
            Frame::HelloEpoch {
                epoch: 3,
                orig_rank: 2,
                next_t: 17,
                port: 45_021,
                standby_port: 45_022,
            },
            Frame::HelloJoin {
                orig_rank: 2,
                port: 0,
                standby_port: 39_999,
            },
            Frame::WelcomeEpoch {
                epoch: 3,
                rank: 1,
                world: vec![0, 2, 3],
                resume_t: 17,
                right_addr: "127.0.0.1:29501".to_string(),
                snapshot: vec![1, 2, 3, 4],
                succession: vec![
                    "127.0.0.1:29500".to_string(),
                    "127.0.0.1:40001".to_string(),
                    String::new(),
                ],
            },
            Frame::WelcomeEpoch {
                epoch: 1,
                rank: 0,
                world: vec![0],
                resume_t: 0,
                right_addr: String::new(),
                snapshot: Vec::new(),
                succession: Vec::new(),
            },
            Frame::Abort {
                rank: ABORT_RANK_UNKNOWN,
                generation: 9,
            },
        ] {
            let bytes = encode_frame(&f);
            assert_eq!(decode_frame(&bytes).unwrap(), f);
            for k in 0..bytes.len() {
                assert!(
                    decode_frame(&bytes[..k]).is_err(),
                    "truncated epoch frame at {k} must be rejected"
                );
            }
        }
    }
}
