//! [`TcpTransport`] — the [`Transport`] impl over `std::net::TcpStream`.
//!
//! Topology: hub-mediated star. Rank 0 (the hub) keeps one stream per
//! peer; every all-gather round, each client sends its contribution as a
//! generation-stamped [`Frame::Data`], the hub collects the full board
//! (its own message in slot 0), encodes the board once into a persistent
//! buffer, and fans the identical rank-indexed byte sequence out to
//! every client. Both ends reuse one encode and one decode buffer across
//! rounds (no per-frame `Vec::new()`), and board payloads are
//! `Arc`-shared [`Message`]s, so the only per-round copies are the
//! unavoidable socket reads/writes. TCP gives per-peer ordering; the
//! explicit generation counter turns any cross-rank divergence (a rank
//! running a different round than the hub) into a typed
//! [`Error::Protocol`] instead of silently mixing rounds.
//!
//! The reduce-scatter → all-gather collective keeps the star's begin
//! path (clients write their contribution eagerly, the hub stashes its
//! own), but the hub runs the whole canonical reduce itself — inherent
//! to a star topology — and fans out ONE reduced vector instead of the
//! n-entry board: per-client received bytes drop from `n·k` to `k`
//! (the hub's NIC still carries `2(n-1)·k`,
//! [`CostModel::rsag_link_bytes_star_hub`]).
//!
//! Failure semantics:
//! * every read/write carries the `io_timeout` deadline from [`NetCfg`],
//!   so a dead or wedged peer surfaces [`Error::Net`] within the timeout
//!   on every rank — no deadlocks;
//! * [`Transport::abort`] poisons the transport: it best-effort sends
//!   [`Frame::Abort`] — stamped with the failed rank and the round
//!   generation — and then shuts both socket directions down, so peers
//!   blocked in a read error out immediately (EOF / garbage frames)
//!   rather than waiting out their timeout. A poisoned transport
//!   surfaces the typed [`Error::PeerLost`] (or [`Error::Poisoned`]
//!   when no attribution arrived), which the elastic layer reads as
//!   "drain this epoch and re-form".
//!
//! [Error::PeerLost]: crate::error::Error::PeerLost
//! [Error::Poisoned]: crate::error::Error::Poisoned
//!
//! [NetCfg]: crate::cluster::net::handshake::NetCfg
//! [CostModel::rsag_link_bytes_star_hub]: crate::collectives::CostModel::rsag_link_bytes_star_hub

use crate::cluster::net::codec::{
    encode_frame, encode_frame_append, read_frame_counted, write_bytes, Frame,
};
use crate::cluster::net::handshake::{client_rendezvous, hub_rendezvous, NetCfg};
use crate::cluster::transport::{
    envelope_mismatch, rsag_reduce_board_into, FloatBufPool, Message, RoundToken, SparseRound,
    Transport,
};
use crate::cluster::CollectiveKind;
use crate::collectives::sparse::{
    canonicalize_residual, reduce_sparse_contributions_with, SparseReduceScratch, SparseVec,
};
use crate::error::{Error, Result};
use crate::obs::{FlightRecorder, ObsCounters, RecKind};
use std::net::{Shutdown, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Sentinel for [`TcpTransport::poisoned_by`]: nobody attributed yet.
const NO_ATTRIBUTION: u64 = u64::MAX;

enum Conn {
    /// Rank 0: one stream per peer rank (slot 0 unused).
    Hub { peers: Vec<Option<TcpStream>> },
    /// Ranks 1..n: the single stream to the hub.
    Client { hub: TcpStream },
}

struct State {
    conn: Conn,
    generation: u64,
    /// Persistent encode buffer: a client's contribution frame, or the
    /// hub's once-encoded whole-board fan-out bytes.
    enc_buf: Vec<u8>,
    /// Persistent decode scratch for incoming frame bodies.
    dec_buf: Vec<u8>,
    /// `true` between a split-phase begin and its complete/abandon —
    /// rejects double-starts (one outstanding round per rank).
    pending: bool,
}

/// Socket transport for one process-local rank of an n-rank cluster.
pub struct TcpTransport {
    n: usize,
    rank: usize,
    state: Mutex<State>,
    /// Membership epoch this transport was formed at: 0 for the initial
    /// rendezvous, bumped instances are assembled by the elastic layer
    /// after a re-formation.
    epoch: u64,
    /// `try_clone`d handles used only by [`Transport::abort`], which must
    /// not take the state lock (a blocked round holds it).
    shutdown_handles: Vec<TcpStream>,
    poisoned: AtomicBool,
    /// Rank attributed with the poisoning ([`NO_ATTRIBUTION`] until
    /// poisoned; first attribution wins).
    poisoned_by: AtomicU64,
    /// Mirror of the state generation, updated at begin/complete, so
    /// [`Transport::abort`] can stamp its notice without taking the
    /// state lock (a blocked — or panicking — round may hold it).
    gen_mirror: AtomicU64,
    /// Wire/payload/round counters for this process's rank, bumped at
    /// the exact read/write sites so gross bytes match the stream.
    obs: ObsCounters,
    /// `--obs-flight` recorder; empty (and costless) unless attached.
    flight: OnceLock<Arc<FlightRecorder>>,
}

impl TcpTransport {
    /// Rank 0: bind the rendezvous address and wait for ranks `1..n`.
    pub fn hub(n: usize, cfg: &NetCfg) -> Result<Self> {
        if n == 0 {
            return Err(Error::invalid("world size must be >= 1"));
        }
        let peers = hub_rendezvous(n, cfg)?;
        Self::hub_from_parts(n, peers, 0)
    }

    /// Rank 0 over already-rendezvoused streams. The elastic layer uses
    /// this after an epoch re-formation: the `HelloEpoch` rendezvous
    /// streams *become* the data-path streams of the new star.
    pub(crate) fn hub_from_parts(
        n: usize,
        peers: Vec<Option<TcpStream>>,
        epoch: u64,
    ) -> Result<Self> {
        let mut handles = Vec::new();
        for s in peers.iter().flatten() {
            handles.push(s.try_clone()?);
        }
        Ok(TcpTransport {
            n,
            rank: 0,
            state: Mutex::new(State {
                conn: Conn::Hub { peers },
                generation: 0,
                enc_buf: Vec::new(),
                dec_buf: Vec::new(),
                pending: false,
            }),
            epoch,
            shutdown_handles: handles,
            poisoned: AtomicBool::new(false),
            poisoned_by: AtomicU64::new(NO_ATTRIBUTION),
            gen_mirror: AtomicU64::new(0),
            obs: ObsCounters::new(),
            flight: OnceLock::new(),
        })
    }

    /// Ranks 1..n: dial the hub and claim `rank`.
    pub fn client(n: usize, rank: usize, cfg: &NetCfg) -> Result<Self> {
        let hub = client_rendezvous(n, rank, cfg)?;
        Self::client_from_parts(n, rank, hub, 0)
    }

    /// Ranks 1..n over an already-rendezvoused hub stream (the epoch
    /// re-formation path, mirroring [`TcpTransport::hub_from_parts`]).
    pub(crate) fn client_from_parts(
        n: usize,
        rank: usize,
        hub: TcpStream,
        epoch: u64,
    ) -> Result<Self> {
        let handle = hub.try_clone()?;
        Ok(TcpTransport {
            n,
            rank,
            state: Mutex::new(State {
                conn: Conn::Client { hub },
                generation: 0,
                enc_buf: Vec::new(),
                dec_buf: Vec::new(),
                pending: false,
            }),
            epoch,
            shutdown_handles: vec![handle],
            poisoned: AtomicBool::new(false),
            poisoned_by: AtomicU64::new(NO_ATTRIBUTION),
            gen_mirror: AtomicU64::new(0),
            obs: ObsCounters::new(),
            flight: OnceLock::new(),
        })
    }

    /// The rank this transport speaks for.
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// The typed fault a poisoned transport surfaces: attributed to the
    /// rank that died when known, anonymous otherwise.
    fn poison_fault(&self, generation: u64) -> Error {
        match self.poisoned_by.load(Ordering::SeqCst) {
            NO_ATTRIBUTION => Error::poisoned(generation),
            r => Error::peer_lost(r as usize, generation),
        }
    }

    /// Poison the transport, attributing the failure to `by`: best-effort
    /// [`Frame::Abort`] notice (stamped from the generation mirror — the
    /// state lock may be held by the very round that is failing), then
    /// shut every socket down so blocked peers error out immediately.
    /// Every call lands a flight event; the counter bump and recorder
    /// dump fire on the first poisoning only.
    fn poison(&self, by: usize) {
        let already = self.poisoned.swap(true, Ordering::SeqCst);
        let _ = self.poisoned_by.compare_exchange(
            NO_ATTRIBUTION,
            by as u64,
            Ordering::SeqCst,
            Ordering::SeqCst,
        );
        let generation = self.gen_mirror.load(Ordering::SeqCst);
        let abort_bytes = encode_frame(&Frame::Abort {
            rank: by as u32,
            generation,
        });
        for h in &self.shutdown_handles {
            // best-effort polite notice, then force any blocked peer read
            // to return; both may fail on an already-dead socket
            let mut w: &TcpStream = h;
            let _ = write_bytes(&mut w, &abort_bytes);
            let _ = h.shutdown(Shutdown::Both);
        }
        if let Some(fr) = self.flight.get() {
            fr.record(RecKind::Abort, generation, by as u64, 0);
            if !already {
                fr.dump_to_log("abort poisoning");
            }
        }
        if !already {
            self.obs.abort();
        }
    }

    /// Read one frame with full obs accounting: gross wire bytes at the
    /// stream boundary, model-unit payload bytes, frame count, and —
    /// when a recorder is attached — a flight event. Deadline expiries
    /// are counted apart from peer loss, and either failure dumps the
    /// recorder for the postmortem.
    fn read_counted(
        &self,
        stream: &mut TcpStream,
        dec_buf: &mut Vec<u8>,
        generation: u64,
    ) -> Result<Frame> {
        match read_frame_counted(stream, dec_buf) {
            Ok((frame, gross)) => {
                self.obs.wire_rx(gross);
                self.obs.frame_decoded();
                self.obs.payload_rx(frame.payload_bytes());
                if let Some(fr) = self.flight.get() {
                    fr.record(RecKind::FrameRx, generation, gross as u64, 0);
                }
                Ok(frame)
            }
            Err(e) => {
                if e.is_timeout() {
                    self.obs.deadline_wait();
                    if let Some(fr) = self.flight.get() {
                        fr.record(RecKind::Deadline, generation, 0, 0);
                        fr.dump_to_log("deadline expiry");
                    }
                } else if let Some(fr) = self.flight.get() {
                    fr.dump_to_log("mid-round peer loss");
                }
                Err(e)
            }
        }
    }

    /// Write pre-encoded frame bytes with full obs accounting; `payload`
    /// is the model-unit byte count the buffer carries.
    fn write_counted(
        &self,
        stream: &mut TcpStream,
        bytes: &[u8],
        payload: usize,
        generation: u64,
    ) -> Result<()> {
        write_bytes(stream, bytes)?;
        self.obs.wire_tx(bytes.len());
        self.obs.payload_tx(payload);
        if let Some(fr) = self.flight.get() {
            fr.record(RecKind::FrameTx, generation, bytes.len() as u64, payload as u64);
        }
        Ok(())
    }

    /// Shared begin path for both collective kinds: validate, claim the
    /// round, and (on a client) put the contribution on the wire. The
    /// trait wrappers add the per-kind round counter on top.
    fn begin_inner(&self, rank: usize, msg: Message) -> Result<RoundToken> {
        if rank != self.rank {
            return Err(Error::invalid(format!(
                "this process's transport speaks for rank {}, not rank {rank}",
                self.rank
            )));
        }
        if self.poisoned.load(Ordering::SeqCst) {
            return Err(self.poison_fault(self.gen_mirror.load(Ordering::SeqCst)));
        }
        let mut guard = self.state.lock().unwrap();
        let State {
            conn,
            generation,
            enc_buf,
            pending,
            ..
        } = &mut *guard;
        if *pending {
            return Err(Error::invariant(format!(
                "rank {} double-started a split-phase round (round {} is still \
                 in flight — finish or drop it first)",
                self.rank, *generation
            )));
        }
        let my_gen = *generation;
        self.gen_mirror.store(my_gen, Ordering::SeqCst);
        let token = match conn {
            Conn::Hub { .. } => {
                // the hub *receives* first: its own contribution is
                // stashed on the token and the collect/fan-out runs at
                // complete. The genuine overlap on the hub side is the
                // clients' contributions accumulating in the kernel
                // socket buffers during the begin→complete gap.
                RoundToken::deferred_with_stash(my_gen, msg)
            }
            Conn::Client { hub } => {
                // the contribution goes on the wire NOW — the overlap
                // window between begin and complete is real transfer time
                let payload = msg.payload_bytes();
                enc_buf.clear();
                encode_frame_append(
                    &Frame::Data {
                        generation: my_gen,
                        msg,
                    },
                    enc_buf,
                );
                self.obs.frame_encoded();
                self.write_counted(hub, enc_buf, payload, my_gen)
                    .map_err(|e| Error::net(format!("sending contribution to hub: {e}")))?;
                RoundToken::deferred(my_gen)
            }
        };
        *pending = true;
        Ok(token)
    }
}

impl Transport for TcpTransport {
    fn n_ranks(&self) -> usize {
        self.n
    }

    fn allgather(&self, rank: usize, msg: Message) -> Result<Arc<[Message]>> {
        // the blocking round is the split phases back to back
        let token = self.allgather_begin(rank, msg)?;
        self.allgather_complete(rank, token)
    }

    fn allgather_begin(&self, rank: usize, msg: Message) -> Result<RoundToken> {
        let token = self.begin_inner(rank, msg)?;
        self.obs.round(CollectiveKind::Allgather);
        if let Some(fr) = self.flight.get() {
            fr.record(RecKind::RoundBegin, token.generation(), 0, 0);
        }
        Ok(token)
    }

    fn rsag_begin(&self, rank: usize, contribution: Arc<Vec<f32>>) -> Result<RoundToken> {
        // identical wire behaviour to the all-gather begin (the
        // contribution goes out eagerly); overridden so the round lands
        // in the rsag counter, not the all-gather one
        let token = self.begin_inner(rank, Message::Floats(contribution))?;
        self.obs.round(CollectiveKind::Rsag);
        if let Some(fr) = self.flight.get() {
            fr.record(RecKind::RoundBegin, token.generation(), 1, 0);
        }
        Ok(token)
    }

    fn allgather_complete(&self, rank: usize, mut token: RoundToken) -> Result<Arc<[Message]>> {
        if rank != self.rank {
            return Err(Error::invalid(format!(
                "this process's transport speaks for rank {}, not rank {rank}",
                self.rank
            )));
        }
        let mut guard = self.state.lock().unwrap();
        let State {
            conn,
            generation,
            enc_buf,
            dec_buf,
            pending,
        } = &mut *guard;
        if !*pending {
            return Err(Error::invariant(format!(
                "rank {} completing a round it never started",
                self.rank
            )));
        }
        // cleared up front: an erroring round poisons the transport (the
        // worker contract), so there is nothing left to hand back anyway
        *pending = false;
        let my_gen = *generation;
        if token.generation() != my_gen {
            return Err(Error::invariant(format!(
                "rank {} completing round {}, but the transport is at round {my_gen}",
                self.rank,
                token.generation()
            )));
        }
        if self.poisoned.load(Ordering::SeqCst) {
            return Err(self.poison_fault(my_gen));
        }
        let n = self.n;
        // any early `?` below leaves the generation unchanged; the failed
        // worker aborts the transport, so no later round can mix with it
        let board: Arc<[Message]> = match conn {
            Conn::Hub { peers } => {
                let msg = token.take_stash().ok_or_else(|| {
                    Error::invariant("hub round token lost its stashed contribution")
                })?;
                let mut slots: Vec<Option<Message>> = (0..n).map(|_| None).collect();
                slots[0] = Some(msg);
                for r in 1..n {
                    let stream = peers[r]
                        .as_mut()
                        .expect("hub rendezvous filled every peer slot");
                    let frame = self.read_counted(stream, dec_buf, my_gen).map_err(|e| {
                        Error::net(format!("reading rank {r}'s contribution: {e}"))
                    })?;
                    slots[r] = Some(super::expect_data(frame, my_gen, &format!("rank {r}"))?);
                }
                let board: Arc<[Message]> = slots
                    .into_iter()
                    .map(|m| m.expect("all slots filled"))
                    .collect();
                // encode the rank-indexed board once into the persistent
                // buffer, fan the same bytes out (payloads are Arc-shared
                // with the board — cloning a Message copies no elements)
                enc_buf.clear();
                for m in board.iter() {
                    encode_frame_append(
                        &Frame::Data {
                            generation: my_gen,
                            msg: m.clone(),
                        },
                        enc_buf,
                    );
                    self.obs.frame_encoded();
                }
                let board_payload: usize = board.iter().map(|m| m.payload_bytes()).sum();
                for r in 1..n {
                    let stream = peers[r].as_mut().expect("peer slot filled");
                    self.write_counted(stream, enc_buf, board_payload, my_gen)
                        .map_err(|e| {
                            Error::net(format!("broadcasting board to rank {r}: {e}"))
                        })?;
                }
                board
            }
            Conn::Client { hub } => {
                // the contribution went out in begin; only the board
                // read-back remains
                let mut board = Vec::with_capacity(n);
                for r in 0..n {
                    let frame = self.read_counted(hub, dec_buf, my_gen).map_err(|e| {
                        Error::net(format!("reading board entry {r} from hub: {e}"))
                    })?;
                    board.push(super::expect_data(frame, my_gen, "hub")?);
                }
                board.into()
            }
        };
        *generation = my_gen.wrapping_add(1);
        self.gen_mirror.store(my_gen.wrapping_add(1), Ordering::SeqCst);
        if let Some(fr) = self.flight.get() {
            fr.record(RecKind::RoundComplete, my_gen, 0, 0);
        }
        Ok(board)
    }

    fn allgather_abandon(&self, rank: usize, token: RoundToken) {
        // the hub must still collect + fan out (clients are waiting on
        // the board) and a client must drain its board read-back so the
        // stream stays round-aligned: run the round to completion and
        // discard the board; a broken round poisons the transport so
        // nobody waits out a dead socket
        if self.allgather_complete(rank, token).is_err() {
            self.abort();
        }
    }

    fn rsag_complete(
        &self,
        rank: usize,
        mut token: RoundToken,
        shards: &mut FloatBufPool,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        if rank != self.rank {
            return Err(Error::invalid(format!(
                "this process's transport speaks for rank {}, not rank {rank}",
                self.rank
            )));
        }
        let mut guard = self.state.lock().unwrap();
        let State {
            conn,
            generation,
            enc_buf,
            dec_buf,
            pending,
        } = &mut *guard;
        if !*pending {
            return Err(Error::invariant(format!(
                "rank {} completing a round it never started",
                self.rank
            )));
        }
        *pending = false;
        let my_gen = *generation;
        if token.generation() != my_gen {
            return Err(Error::invariant(format!(
                "rank {} completing round {}, but the transport is at round {my_gen}",
                self.rank,
                token.generation()
            )));
        }
        if self.poisoned.load(Ordering::SeqCst) {
            return Err(self.poison_fault(my_gen));
        }
        let n = self.n;
        match conn {
            Conn::Hub { peers } => {
                let msg = token.take_stash().ok_or_else(|| {
                    Error::invariant("hub round token lost its stashed contribution")
                })?;
                let mut board: Vec<Message> = Vec::with_capacity(n);
                board.push(msg);
                for r in 1..n {
                    let stream = peers[r]
                        .as_mut()
                        .expect("hub rendezvous filled every peer slot");
                    let frame = self.read_counted(stream, dec_buf, my_gen).map_err(|e| {
                        Error::net(format!("reading rank {r}'s contribution: {e}"))
                    })?;
                    board.push(super::expect_data(frame, my_gen, &format!("rank {r}"))?);
                }
                // the hub runs the whole canonical reduce — inherent to a
                // star — and fans out ONE reduced vector: per-client
                // received bytes drop from n·k to k
                rsag_reduce_board_into(&board, out)?;
                let reduced = shards.fill(|buf| buf.extend_from_slice(out));
                let reduced_msg = Message::Floats(reduced);
                let reduced_payload = reduced_msg.payload_bytes();
                enc_buf.clear();
                encode_frame_append(
                    &Frame::Data {
                        generation: my_gen,
                        msg: reduced_msg,
                    },
                    enc_buf,
                );
                self.obs.frame_encoded();
                for r in 1..n {
                    let stream = peers[r].as_mut().expect("peer slot filled");
                    self.write_counted(stream, enc_buf, reduced_payload, my_gen)
                        .map_err(|e| {
                            Error::net(format!("broadcasting reduced vector to rank {r}: {e}"))
                        })?;
                }
            }
            Conn::Client { hub } => {
                // the contribution went out in begin; the hub sends back
                // one already-reduced vector instead of the n-entry board
                let frame = self.read_counted(hub, dec_buf, my_gen).map_err(|e| {
                    Error::net(format!("reading reduced vector from hub: {e}"))
                })?;
                match super::expect_data(frame, my_gen, "hub")? {
                    Message::Floats(v) => {
                        out.clear();
                        out.extend_from_slice(&v);
                    }
                    other => return Err(envelope_mismatch("Floats", &other)),
                }
            }
        }
        *generation = my_gen.wrapping_add(1);
        self.gen_mirror.store(my_gen.wrapping_add(1), Ordering::SeqCst);
        if let Some(fr) = self.flight.get() {
            fr.record(RecKind::RoundComplete, my_gen, 1, 0);
        }
        Ok(())
    }

    fn rsag_abandon(&self, rank: usize, token: RoundToken) {
        // same stream-alignment argument as allgather_abandon: run the
        // round to completion (the hub must reduce + fan out, a client
        // must drain its reduced-vector read) and discard the result
        let mut shards = FloatBufPool::new();
        let mut out = Vec::new();
        if self.rsag_complete(rank, token, &mut shards, &mut out).is_err() {
            self.abort();
        }
    }

    fn rsag_sparse_begin(
        &self,
        rank: usize,
        contribution: Arc<SparseVec>,
        round: SparseRound,
    ) -> Result<RoundToken> {
        // identical wire behaviour to the dense rsag begin: a client's
        // entry list goes out eagerly as one Message::Sparse, the hub
        // stashes its own until the collect at complete
        let _ = round;
        let token = self.begin_inner(rank, Message::Sparse(contribution))?;
        self.obs.round(CollectiveKind::Rsag);
        if let Some(fr) = self.flight.get() {
            fr.record(RecKind::RoundBegin, token.generation(), 2, 0);
        }
        Ok(token)
    }

    fn rsag_sparse_complete(
        &self,
        rank: usize,
        mut token: RoundToken,
        round: SparseRound,
        scratch: &mut SparseReduceScratch,
        out: &mut SparseVec,
        residual: &mut SparseVec,
    ) -> Result<()> {
        if rank != self.rank {
            return Err(Error::invalid(format!(
                "this process's transport speaks for rank {}, not rank {rank}",
                self.rank
            )));
        }
        let mut guard = self.state.lock().unwrap();
        let State {
            conn,
            generation,
            enc_buf,
            dec_buf,
            pending,
        } = &mut *guard;
        if !*pending {
            return Err(Error::invariant(format!(
                "rank {} completing a round it never started",
                self.rank
            )));
        }
        *pending = false;
        let my_gen = *generation;
        if token.generation() != my_gen {
            return Err(Error::invariant(format!(
                "rank {} completing round {}, but the transport is at round {my_gen}",
                self.rank,
                token.generation()
            )));
        }
        if self.poisoned.load(Ordering::SeqCst) {
            return Err(self.poison_fault(my_gen));
        }
        let n = self.n;
        let bound_check = |s: &SparseVec, who: &str| -> Result<()> {
            match s.idx.last() {
                Some(&last) if last as usize >= round.union_len => Err(Error::protocol(format!(
                    "{who}'s sparse entries index position {last}, union length \
                     is {} — workers diverged",
                    round.union_len
                ))),
                _ => Ok(()),
            }
        };
        match conn {
            Conn::Hub { peers } => {
                let msg = token.take_stash().ok_or_else(|| {
                    Error::invariant("hub round token lost its stashed contribution")
                })?;
                let mut board: Vec<Message> = Vec::with_capacity(n);
                board.push(msg);
                for r in 1..n {
                    let stream = peers[r]
                        .as_mut()
                        .expect("hub rendezvous filled every peer slot");
                    let frame = self.read_counted(stream, dec_buf, my_gen).map_err(|e| {
                        Error::net(format!("reading rank {r}'s contribution: {e}"))
                    })?;
                    board.push(super::expect_data(frame, my_gen, &format!("rank {r}"))?);
                }
                for (r, m) in board.iter().enumerate() {
                    match m {
                        Message::Sparse(s) => bound_check(s, &format!("rank {r}"))?,
                        other => return Err(envelope_mismatch("Sparse", other)),
                    }
                }
                // the hub replays the whole canonical reduce — inherent
                // to a star — so it also owns every rank's re-selection
                // discards and mails each rank its own residual back
                let mut residuals: Vec<SparseVec> = (0..n).map(|_| SparseVec::new()).collect();
                reduce_sparse_contributions_with(
                    n,
                    round.union_len,
                    |r| match &board[r] {
                        Message::Sparse(s) => (&s.idx[..], &s.val[..]),
                        _ => unreachable!("validated above"),
                    },
                    round.shard_k,
                    scratch,
                    out,
                    |owner, i, v| residuals[owner].push_entry(i, v),
                );
                for res in residuals.iter_mut() {
                    canonicalize_residual(res, scratch);
                }
                // fan out the reduced entries (one encode, n-1 writes)
                let reduced_msg = Message::Sparse(Arc::new(out.clone()));
                let reduced_payload = reduced_msg.payload_bytes();
                enc_buf.clear();
                encode_frame_append(
                    &Frame::Data {
                        generation: my_gen,
                        msg: reduced_msg,
                    },
                    enc_buf,
                );
                self.obs.frame_encoded();
                for r in 1..n {
                    let stream = peers[r].as_mut().expect("peer slot filled");
                    self.write_counted(stream, enc_buf, reduced_payload, my_gen)
                        .map_err(|e| {
                            Error::net(format!("broadcasting reduced entries to rank {r}: {e}"))
                        })?;
                }
                // residual frames travel only under an active cap — at
                // shard_k == 0 every residual is empty and the frames
                // are skipped entirely, so uncapped sparse rounds keep
                // the exact star byte form the model predicts
                if round.shard_k > 0 {
                    for r in 1..n {
                        let res_msg =
                            Message::Sparse(Arc::new(std::mem::take(&mut residuals[r])));
                        let res_payload = res_msg.payload_bytes();
                        enc_buf.clear();
                        encode_frame_append(
                            &Frame::Data {
                                generation: my_gen,
                                msg: res_msg,
                            },
                            enc_buf,
                        );
                        self.obs.frame_encoded();
                        let stream = peers[r].as_mut().expect("peer slot filled");
                        self.write_counted(stream, enc_buf, res_payload, my_gen)
                            .map_err(|e| {
                                Error::net(format!("sending residual to rank {r}: {e}"))
                            })?;
                    }
                }
                let own = &residuals[0];
                residual.copy_from(&own.idx, &own.val);
            }
            Conn::Client { hub } => {
                // the contribution went out in begin; the hub sends back
                // the reduced entries and (only under a cap) this rank's
                // residual
                let frame = self.read_counted(hub, dec_buf, my_gen).map_err(|e| {
                    Error::net(format!("reading reduced entries from hub: {e}"))
                })?;
                match super::expect_data(frame, my_gen, "hub")? {
                    Message::Sparse(s) => {
                        bound_check(&s, "hub's reduced entries")?;
                        out.copy_from(&s.idx, &s.val);
                    }
                    other => return Err(envelope_mismatch("Sparse", &other)),
                }
                residual.clear();
                if round.shard_k > 0 {
                    let frame = self.read_counted(hub, dec_buf, my_gen).map_err(|e| {
                        Error::net(format!("reading residual from hub: {e}"))
                    })?;
                    match super::expect_data(frame, my_gen, "hub")? {
                        Message::Sparse(s) => {
                            bound_check(&s, "hub's residual")?;
                            residual.copy_from(&s.idx, &s.val);
                        }
                        other => return Err(envelope_mismatch("Sparse", &other)),
                    }
                }
            }
        }
        *generation = my_gen.wrapping_add(1);
        self.gen_mirror.store(my_gen.wrapping_add(1), Ordering::SeqCst);
        if let Some(fr) = self.flight.get() {
            fr.record(RecKind::RoundComplete, my_gen, 2, 0);
        }
        Ok(())
    }

    fn rsag_sparse_abandon(&self, rank: usize, token: RoundToken, round: SparseRound) {
        // same stream-alignment argument as rsag_abandon: the hub must
        // reduce + fan out, a client must drain its read-backs
        let mut scratch = SparseReduceScratch::new();
        let mut out = SparseVec::new();
        let mut residual = SparseVec::new();
        if self
            .rsag_sparse_complete(rank, token, round, &mut scratch, &mut out, &mut residual)
            .is_err()
        {
            self.abort();
        }
    }

    fn abort(&self) {
        // a local abort means THIS worker failed: peers learn which rank
        // died from the stamped notice
        self.poison(self.rank);
    }

    fn abort_from(&self, rank: usize) {
        self.poison(rank);
    }

    fn epoch(&self) -> u64 {
        self.epoch
    }

    fn counters(&self, rank: usize) -> Option<&ObsCounters> {
        (rank == self.rank).then_some(&self.obs)
    }

    fn attach_flight_recorder(&self, rank: usize, recorder: Arc<FlightRecorder>) {
        if rank == self.rank {
            let _ = self.flight.set(recorder);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::net::handshake::free_loopback_addr;
    use crate::cluster::transport::Endpoint;
    use std::time::Duration;

    fn cfg(addr: &str) -> NetCfg {
        NetCfg {
            coord_addr: addr.to_string(),
            connect_timeout: Duration::from_secs(10),
            io_timeout: Duration::from_secs(5),
        }
    }

    /// Build an n-rank loopback cluster: returns one joined transport
    /// per rank (hub at index 0), built concurrently.
    fn loopback_cluster(n: usize) -> Vec<Arc<TcpTransport>> {
        let addr = free_loopback_addr().unwrap();
        let mut client_handles = Vec::new();
        for rank in 1..n {
            let c = cfg(&addr);
            client_handles.push(std::thread::spawn(move || {
                TcpTransport::client(n, rank, &c).map(Arc::new)
            }));
        }
        let hub = Arc::new(TcpTransport::hub(n, &cfg(&addr)).unwrap());
        let mut out = vec![hub];
        for h in client_handles {
            out.push(h.join().unwrap().unwrap());
        }
        out
    }

    #[test]
    fn allgather_is_rank_indexed_over_rounds() {
        let n = 3;
        let rounds = 20;
        let tps = loopback_cluster(n);
        let mut handles = Vec::new();
        for (rank, tp) in tps.into_iter().enumerate() {
            handles.push(std::thread::spawn(move || {
                let ep = Endpoint::new(rank, tp.as_ref());
                for round in 0..rounds {
                    let mine = (rank * 1000 + round) as f64;
                    let got = ep.allgather_f64(mine).unwrap();
                    let want: Vec<f64> = (0..n).map(|r| (r * 1000 + round) as f64).collect();
                    assert_eq!(got, want, "rank {rank} round {round}");
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn mixed_message_kinds_roundtrip() {
        use crate::coordinator::SelectOutput;
        let n = 2;
        let tps = loopback_cluster(n);
        let mut handles = Vec::new();
        for (rank, tp) in tps.into_iter().enumerate() {
            handles.push(std::thread::spawn(move || {
                let ep = Endpoint::new(rank, tp.as_ref());
                let sel = Arc::new(SelectOutput {
                    idx: vec![rank as u32, 100 + rank as u32],
                    val: vec![rank as f32, f32::NAN],
                });
                let sels = ep.allgather_select(sel).unwrap();
                assert_eq!(sels.len(), n);
                assert_eq!(sels[rank].idx[0], rank as u32);
                assert!(sels[0].val[1].is_nan() && sels[1].val[1].is_nan());
                let floats = ep.allgather_floats(Arc::new(vec![rank as f32; 4])).unwrap();
                assert_eq!(*floats[1], vec![1.0f32; 4]);
                // empty selection survives the wire
                let empty = ep
                    .allgather_select(Arc::new(SelectOutput::default()))
                    .unwrap();
                assert!(empty.iter().all(|s| s.is_empty()));
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn rsag_reduces_on_the_hub_and_fans_out_one_vector() {
        use crate::collectives::reduce_contributions_rsag_with;
        let n = 3;
        let len = 8;
        // magnitude data makes the canonical order observable in f32
        fn probe(rank: usize, round: usize, len: usize) -> Vec<f32> {
            const VALS: [f32; 3] = [1.0e8, 1.0, -1.0e8];
            (0..len).map(|i| VALS[(rank + i + round) % 3]).collect()
        }
        let tps = loopback_cluster(n);
        let mut handles = Vec::new();
        for (rank, tp) in tps.into_iter().enumerate() {
            handles.push(std::thread::spawn(move || {
                let ep = Endpoint::new(rank, tp.as_ref());
                let mut shards = crate::cluster::transport::FloatBufPool::new();
                let mut out = Vec::new();
                for round in 0..6 {
                    ep.reduce_scatter_allgather(
                        Arc::new(probe(rank, round, len)),
                        &mut shards,
                        &mut out,
                    )
                    .unwrap();
                    let parts: Vec<Vec<f32>> = (0..n).map(|r| probe(r, round, len)).collect();
                    let mut want = Vec::new();
                    reduce_contributions_rsag_with(n, len, |r| &parts[r][..], &mut want);
                    let got: Vec<u32> = out.iter().map(|x| x.to_bits()).collect();
                    let want: Vec<u32> = want.iter().map(|x| x.to_bits()).collect();
                    assert_eq!(got, want, "rank {rank} round {round}");
                    // rounds of either collective kind interleave
                    let echo = ep.allgather_f64(rank as f64).unwrap();
                    assert_eq!(echo, (0..n).map(|r| r as f64).collect::<Vec<f64>>());
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn sparse_rsag_reduces_on_the_hub_and_mails_residuals() {
        use crate::collectives::sparse_shard_allreduce_lockstep;
        use crate::collectives::CostModel;
        let n = 3;
        let len = 10;
        // strided disjoint selections with magnitude probes: caps force
        // real re-selection and the f32 bits expose order divergence
        fn probe(rank: usize, round: usize, n: usize, len: usize) -> SparseVec {
            const VALS: [f32; 3] = [1.0e8, 1.0, -1.0e8];
            let mut sv = SparseVec::new();
            let mut pos = rank;
            while pos < len {
                sv.push(pos as u32, VALS[(rank + pos + round) % 3]);
                pos += n;
            }
            sv
        }
        let tps = loopback_cluster(n);
        let mut handles = Vec::new();
        for (rank, tp) in tps.into_iter().enumerate() {
            handles.push(std::thread::spawn(move || {
                let mut scratch = SparseReduceScratch::new();
                let mut out = SparseVec::new();
                let mut residual = SparseVec::new();
                for round in 0..6 {
                    let shard_k = if round % 2 == 0 { 0 } else { 1 };
                    let rd = SparseRound {
                        union_len: len,
                        shard_k,
                    };
                    let mine = Arc::new(probe(rank, round, n, len));
                    tp.rsag_sparse(rank, mine, rd, &mut scratch, &mut out, &mut residual)
                        .unwrap();
                    let contribs: Vec<SparseVec> =
                        (0..n).map(|r| probe(r, round, n, len)).collect();
                    let net = CostModel::paper_testbed(n);
                    let mut tw_scratch = SparseReduceScratch::new();
                    let mut tw_entries = SparseVec::new();
                    let mut tw_reduced = Vec::new();
                    let mut tw_residuals: Vec<SparseVec> =
                        (0..n).map(|_| SparseVec::new()).collect();
                    sparse_shard_allreduce_lockstep(
                        &contribs,
                        len,
                        shard_k,
                        &net,
                        &mut tw_scratch,
                        &mut tw_entries,
                        &mut tw_reduced,
                        &mut tw_residuals,
                    );
                    assert_eq!(out.idx, tw_entries.idx, "rank {rank} round {round}");
                    let got: Vec<u32> = out.val.iter().map(|x| x.to_bits()).collect();
                    let want: Vec<u32> =
                        tw_entries.val.iter().map(|x| x.to_bits()).collect();
                    assert_eq!(got, want, "rank {rank} round {round} values");
                    assert_eq!(
                        residual.idx, tw_residuals[rank].idx,
                        "rank {rank} round {round} residual positions"
                    );
                    let got: Vec<u32> =
                        residual.val.iter().map(|x| x.to_bits()).collect();
                    let want: Vec<u32> =
                        tw_residuals[rank].val.iter().map(|x| x.to_bits()).collect();
                    assert_eq!(got, want, "rank {rank} round {round} residual values");
                    // rounds of every collective kind interleave
                    let echo = Endpoint::new(rank, tp.as_ref()).allgather_f64(rank as f64);
                    assert_eq!(
                        echo.unwrap(),
                        (0..n).map(|r| r as f64).collect::<Vec<f64>>()
                    );
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn wrong_rank_call_is_rejected() {
        let tps = loopback_cluster(2);
        let err = tps[1]
            .allgather(0, Message::Scalar(0.0))
            .unwrap_err()
            .to_string();
        assert!(err.contains("speaks for rank 1"), "{err}");
    }

    #[test]
    fn hub_counters_match_the_star_link_model() {
        use crate::collectives::CostModel;
        let n = 3;
        let len = 12;
        let tps = loopback_cluster(n);
        let hub = tps[0].clone();
        let before = hub.counters(0).unwrap().snapshot();
        let mut handles = Vec::new();
        for (rank, tp) in tps.into_iter().enumerate() {
            handles.push(std::thread::spawn(move || {
                let ep = Endpoint::new(rank, tp.as_ref());
                let mut shards = FloatBufPool::new();
                let mut out = Vec::new();
                ep.allgather_floats(Arc::new(vec![rank as f32; len])).unwrap();
                ep.reduce_scatter_allgather(Arc::new(vec![1.0f32; len]), &mut shards, &mut out)
                    .unwrap();
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let d = hub.counters(0).unwrap().snapshot().since(&before);
        let net = CostModel::paper_testbed(n);
        let b = len * CostModel::DENSE_ENTRY_BYTES;
        // the hub's NIC carries exactly what the star link-byte model
        // charges: (n-1)·B in + (n-1)·n·B out per all-gather round,
        // (n-1)·B each way per rsag round
        let want =
            (net.allgather_link_bytes_star_hub(b) + net.rsag_link_bytes_star_hub(b)) as u64;
        assert_eq!(d.payload_link_bytes(), want);
        assert_eq!(d.rounds_allgather, 1);
        assert_eq!(d.rounds_rsag, 1);
        assert_eq!(d.aborts, 0);
        // gross wire bytes strictly exceed payload bytes (framing)
        assert!(d.wire_rx_bytes > d.payload_rx_bytes, "{d:?}");
        assert!(d.wire_tx_bytes > d.payload_tx_bytes, "{d:?}");
        // out-of-process ranks are not this instance's to count
        assert!(hub.counters(1).is_none());
    }

    #[test]
    fn single_rank_world_needs_no_sockets() {
        let addr = free_loopback_addr().unwrap();
        let tp = TcpTransport::hub(1, &cfg(&addr)).unwrap();
        let got = tp.allgather(0, Message::Scalar(4.5)).unwrap();
        assert_eq!(&got[..], &[Message::Scalar(4.5)]);
    }

    #[test]
    fn poisoned_transport_surfaces_the_attributed_fault() {
        let tps = loopback_cluster(2);
        tps[0].abort_from(1);
        let err = tps[0].allgather(0, Message::Scalar(1.0)).unwrap_err();
        assert!(err.is_membership_fault(), "{err}");
        assert!(err.to_string().contains("peer rank 1 lost"), "{err}");
        // the first attribution wins: a later anonymous-looking abort
        // (a local failure) does not rewrite the postmortem
        tps[0].abort();
        let err = tps[0].allgather(0, Message::Scalar(1.0)).unwrap_err();
        assert!(err.to_string().contains("peer rank 1 lost"), "{err}");
    }

    #[test]
    fn from_parts_constructor_stamps_the_epoch() {
        let tp = TcpTransport::hub_from_parts(1, vec![None], 3).unwrap();
        assert_eq!(tp.epoch(), 3);
        let got = tp.allgather(0, Message::Scalar(2.5)).unwrap();
        assert_eq!(&got[..], &[Message::Scalar(2.5)]);
    }
}
