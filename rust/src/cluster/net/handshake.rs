//! TCP rendezvous: rank 0 is the hub, ranks 1..n dial in and claim
//! their slot.
//!
//! Protocol (all frames from [`codec`]):
//!
//! 1. every client connects to the hub's coordinator address (retrying
//!    while the hub is still binding) and sends
//!    [`Frame::Hello`]`{ world, rank }`;
//! 2. the hub validates the claim — protocol version (checked by frame
//!    decoding), world-size agreement, rank in `1..world`, no duplicate
//!    claims — answering bad claims with [`Frame::Reject`] and dropping
//!    them, without giving up on the slot (a well-behaved claimant may
//!    still arrive before the deadline);
//! 3. once every slot is filled the hub sends [`Frame::Welcome`] to all
//!    clients, releasing them into the collective rounds together.
//!
//! All waits are bounded: the hub polls a non-blocking listener until
//! `connect_timeout`, clients bound their dial-retry loop and their
//! Welcome wait by the same budget, and every stream gets `io_timeout`
//! read/write deadlines before it is handed to the transport.
//!
//! [`codec`]: crate::cluster::net::codec

use crate::cluster::net::codec::{read_frame, write_frame, Frame};
use crate::error::{Error, Result};
use std::net::{TcpListener, TcpStream};
use std::time::{Duration, Instant};

/// Socket-transport tunables, mirrored in TOML under `[transport]`.
#[derive(Clone, Debug)]
pub struct NetCfg {
    /// Rendezvous address the hub binds and clients dial
    /// (`host:port`).
    pub coord_addr: String,
    /// Budget for the whole rendezvous: client dial retries, the hub's
    /// accept loop, and the client's wait for `Welcome`.
    pub connect_timeout: Duration,
    /// Per-read/write deadline during collective rounds; a peer that
    /// stays silent longer than this surfaces [`Error::Net`] instead of
    /// hanging the cluster.
    pub io_timeout: Duration,
}

impl Default for NetCfg {
    fn default() -> Self {
        NetCfg {
            coord_addr: "127.0.0.1:29400".to_string(),
            connect_timeout: Duration::from_secs(30),
            io_timeout: Duration::from_secs(30),
        }
    }
}

fn set_round_timeouts(stream: &TcpStream, cfg: &NetCfg) -> Result<()> {
    stream.set_read_timeout(Some(cfg.io_timeout))?;
    stream.set_write_timeout(Some(cfg.io_timeout))?;
    stream.set_nodelay(true)?;
    Ok(())
}

/// Hub side: bind `coord_addr`, collect one valid [`Frame::Hello`] per
/// rank in `1..n`, then release everyone with [`Frame::Welcome`].
/// Returns the streams rank-indexed (slot 0, the hub itself, is `None`).
pub fn hub_rendezvous(n: usize, cfg: &NetCfg) -> Result<Vec<Option<TcpStream>>> {
    let listener = TcpListener::bind(&cfg.coord_addr).map_err(|e| {
        Error::net(format!("hub cannot bind {}: {e}", cfg.coord_addr))
    })?;
    listener.set_nonblocking(true)?;
    let deadline = Instant::now() + cfg.connect_timeout;
    let mut peers: Vec<Option<TcpStream>> = (0..n).map(|_| None).collect();
    let mut missing = n - 1;
    while missing > 0 {
        // checked every iteration (not only when accept would block), so
        // a stream of garbage connections cannot extend the rendezvous
        // past its budget
        let remaining = deadline.saturating_duration_since(Instant::now());
        if remaining.is_zero() {
            return Err(rendezvous_timeout(&peers, cfg));
        }
        match listener.accept() {
            Ok((stream, _)) => {
                stream.set_nonblocking(false)?;
                // the Hello read must not eat the whole rendezvous
                // budget: a connection that sends nothing (port scanner,
                // peer that died right after connect) is cut off at the
                // deadline so legitimate ranks can still be seated
                stream.set_read_timeout(Some(
                    remaining
                        .min(cfg.io_timeout)
                        .max(Duration::from_millis(10)),
                ))?;
                stream.set_write_timeout(Some(cfg.io_timeout))?;
                stream.set_nodelay(true)?;
                let mut stream = stream;
                match read_frame(&mut stream) {
                    Ok(Frame::Hello { world, rank }) => {
                        let reject = if world as usize != n {
                            Some(format!(
                                "world size mismatch: claim {world}, hub runs {n}"
                            ))
                        } else if rank == 0 || rank as usize >= n {
                            Some(format!("rank {rank} out of range 1..{n}"))
                        } else if peers[rank as usize].is_some() {
                            Some(format!("rank {rank} already claimed"))
                        } else {
                            None
                        };
                        match reject {
                            Some(reason) => {
                                let _ = write_frame(
                                    &mut stream,
                                    &Frame::Reject { reason },
                                );
                                // dropped; keep waiting for a valid claim
                            }
                            None => {
                                peers[rank as usize] = Some(stream);
                                missing -= 1;
                            }
                        }
                    }
                    Ok(other) => {
                        let _ = write_frame(
                            &mut stream,
                            &Frame::Reject {
                                reason: format!("expected Hello, got {other:?}"),
                            },
                        );
                    }
                    Err(_) => {
                        // undecodable (wrong version / garbage): drop it
                    }
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) => return Err(Error::net(format!("hub accept failed: {e}"))),
        }
    }
    for stream in peers.iter_mut().flatten() {
        // seated peers may carry a deadline-clipped read timeout from
        // the Hello phase; reset to the steady-state round deadlines
        set_round_timeouts(stream, cfg)?;
        write_frame(stream, &Frame::Welcome { world: n as u32 })?;
    }
    Ok(peers)
}

fn rendezvous_timeout(peers: &[Option<TcpStream>], cfg: &NetCfg) -> Error {
    let absent: Vec<String> = peers
        .iter()
        .enumerate()
        .skip(1)
        .filter(|(_, s)| s.is_none())
        .map(|(r, _)| r.to_string())
        .collect();
    Error::net(format!(
        "rendezvous timed out after {:?}: still waiting for rank(s) {}",
        cfg.connect_timeout,
        absent.join(", ")
    ))
}

/// Client side: dial the hub (retrying until the deadline — the hub
/// process may not have bound yet), claim `rank`, and wait for
/// [`Frame::Welcome`].
pub fn client_rendezvous(n: usize, rank: usize, cfg: &NetCfg) -> Result<TcpStream> {
    if rank == 0 || rank >= n {
        return Err(Error::invalid(format!(
            "client rank {rank} out of range 1..{n} (rank 0 is the hub)"
        )));
    }
    let deadline = Instant::now() + cfg.connect_timeout;
    let mut stream = loop {
        match TcpStream::connect(&cfg.coord_addr) {
            Ok(s) => break s,
            Err(e) => {
                if Instant::now() >= deadline {
                    return Err(Error::net(format!(
                        "cannot reach hub at {} within {:?}: {e}",
                        cfg.coord_addr, cfg.connect_timeout
                    )));
                }
                std::thread::sleep(Duration::from_millis(25));
            }
        }
    };
    // Welcome may take up to the full rendezvous budget (the hub waits
    // for every rank before releasing anyone)
    stream.set_read_timeout(Some(cfg.connect_timeout))?;
    stream.set_write_timeout(Some(cfg.io_timeout))?;
    stream.set_nodelay(true)?;
    write_frame(
        &mut stream,
        &Frame::Hello {
            world: n as u32,
            rank: rank as u32,
        },
    )?;
    match read_frame(&mut stream)? {
        Frame::Welcome { world } if world as usize == n => {
            set_round_timeouts(&stream, cfg)?;
            Ok(stream)
        }
        Frame::Welcome { world } => Err(Error::protocol(format!(
            "hub confirmed world {world}, expected {n}"
        ))),
        Frame::Reject { reason } => Err(Error::protocol(format!(
            "hub rejected rank {rank}: {reason}"
        ))),
        other => Err(Error::protocol(format!(
            "expected Welcome, got {other:?}"
        ))),
    }
}

/// Pick a free loopback port by binding port 0 and reading it back.
/// There is a small window in which another process could take it, but
/// the single-host launcher hands the address straight to its children.
pub fn free_loopback_addr() -> Result<String> {
    let l = TcpListener::bind("127.0.0.1:0")?;
    let addr = l.local_addr()?;
    Ok(addr.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg(addr: &str) -> NetCfg {
        NetCfg {
            coord_addr: addr.to_string(),
            connect_timeout: Duration::from_secs(10),
            io_timeout: Duration::from_secs(5),
        }
    }

    #[test]
    fn two_rank_rendezvous_completes() {
        let addr = free_loopback_addr().unwrap();
        let cfg = quick_cfg(&addr);
        let cfg2 = cfg.clone();
        let client = std::thread::spawn(move || client_rendezvous(2, 1, &cfg2));
        let peers = hub_rendezvous(2, &cfg).unwrap();
        assert!(peers[0].is_none());
        assert!(peers[1].is_some());
        client.join().unwrap().unwrap();
    }

    #[test]
    fn client_rank_zero_is_rejected_locally() {
        let cfg = quick_cfg("127.0.0.1:1");
        assert!(client_rendezvous(4, 0, &cfg).is_err());
        assert!(client_rendezvous(4, 4, &cfg).is_err());
    }

    #[test]
    fn hub_times_out_when_ranks_missing() {
        let addr = free_loopback_addr().unwrap();
        let cfg = NetCfg {
            coord_addr: addr,
            connect_timeout: Duration::from_millis(200),
            io_timeout: Duration::from_millis(200),
        };
        let err = hub_rendezvous(3, &cfg).unwrap_err().to_string();
        assert!(err.contains("timed out"), "{err}");
        assert!(err.contains('1') && err.contains('2'), "missing ranks listed: {err}");
    }

    #[test]
    fn free_addr_is_bindable() {
        let a = free_loopback_addr().unwrap();
        assert!(a.starts_with("127.0.0.1:"));
        // the port is free again after the probe listener dropped
        let _l = TcpListener::bind(&a).unwrap();
    }
}
