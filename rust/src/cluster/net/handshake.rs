//! TCP rendezvous: rank 0 is the hub, ranks 1..n dial in and claim
//! their slot.
//!
//! Protocol (all frames from [`codec`]):
//!
//! 1. every client connects to the hub's coordinator address (retrying
//!    while the hub is still binding) and sends
//!    [`Frame::Hello`]`{ world, rank }`;
//! 2. the hub validates the claim — protocol version (checked by frame
//!    decoding), world-size agreement, rank in `1..world`, no duplicate
//!    claims — answering bad claims with [`Frame::Reject`] and dropping
//!    them, without giving up on the slot (a well-behaved claimant may
//!    still arrive before the deadline);
//! 3. once every slot is filled the hub sends [`Frame::Welcome`] to all
//!    clients, releasing them into the collective rounds together.
//!
//! All waits are bounded: the hub polls a non-blocking listener until
//! `connect_timeout`, clients bound their dial-retry loop and their
//! Welcome wait by the same budget, and every stream gets `io_timeout`
//! read/write deadlines before it is handed to the transport.
//!
//! Two failure modes the rendezvous rides out rather than aborting on:
//!
//! * **bind races** — the launcher hands out coordinator ports probed
//!   free with [`free_loopback_addr`], whose probe listener is dropped
//!   before the hub binds; [`bind_with_retry`] retries `AddrInUse`
//!   with backoff inside the rendezvous budget instead of failing the
//!   whole cluster on the window.
//! * **dead claimants** — a claimant that dies after Hello but before
//!   Welcome used to burn its rank slot forever (the hub then timed
//!   out waiting for a rank that could never arrive). The accept loop
//!   now probes seated claimants and releases the slot on EOF (or on a
//!   claimant that speaks before Welcome), so a restarted rank can
//!   re-claim it.
//!
//! [`codec`]: crate::cluster::net::codec

use crate::cluster::net::codec::{read_frame, write_frame, Frame};
use crate::error::{Error, Result};
use std::net::{TcpListener, TcpStream};
use std::time::{Duration, Instant};

/// Socket-transport tunables, mirrored in TOML under `[transport]`.
#[derive(Clone, Debug)]
pub struct NetCfg {
    /// Rendezvous address the hub binds and clients dial
    /// (`host:port`).
    pub coord_addr: String,
    /// Budget for the whole rendezvous: client dial retries, the hub's
    /// accept loop, and the client's wait for `Welcome`.
    pub connect_timeout: Duration,
    /// Per-read/write deadline during collective rounds; a peer that
    /// stays silent longer than this surfaces [`Error::Net`] instead of
    /// hanging the cluster.
    pub io_timeout: Duration,
}

impl Default for NetCfg {
    fn default() -> Self {
        NetCfg {
            coord_addr: "127.0.0.1:29400".to_string(),
            connect_timeout: Duration::from_secs(30),
            io_timeout: Duration::from_secs(30),
        }
    }
}

pub(crate) fn set_round_timeouts(stream: &TcpStream, cfg: &NetCfg) -> Result<()> {
    stream.set_read_timeout(Some(cfg.io_timeout))?;
    stream.set_write_timeout(Some(cfg.io_timeout))?;
    stream.set_nodelay(true)?;
    Ok(())
}

/// Bind `addr`, retrying `AddrInUse` with backoff until `deadline`.
/// Closes the window between a [`free_loopback_addr`] probe (or a
/// previous epoch's teardown) and the real bind — transient occupancy
/// is waited out instead of failing the rendezvous.
pub(crate) fn bind_with_retry(addr: &str, deadline: Instant) -> Result<TcpListener> {
    let mut wait = Duration::from_millis(10);
    loop {
        match TcpListener::bind(addr) {
            Ok(l) => return Ok(l),
            Err(e)
                if e.kind() == std::io::ErrorKind::AddrInUse
                    && Instant::now() < deadline =>
            {
                std::thread::sleep(
                    wait.min(deadline.saturating_duration_since(Instant::now())),
                );
                wait = (wait * 2).min(Duration::from_millis(250));
            }
            Err(e) => return Err(Error::net(format!("hub cannot bind {addr}: {e}"))),
        }
    }
}

/// Bounded exponential backoff with deterministic jitter for dial
/// retries: base wait 10 ms doubling to a 250 ms cap (mirroring
/// [`bind_with_retry`]), plus a jitter drawn from an LCG seeded with
/// the dialer's original rank — every rank's retry train is
/// reproducible run-to-run, yet distinct ranks desynchronize instead
/// of hammering a recovering coordinator in lockstep.
pub(crate) struct DialBackoff {
    base: Duration,
    lcg: u64,
    /// Retry attempts taken so far (0 until the first wait).
    pub attempt: u64,
}

impl DialBackoff {
    /// Backoff train seeded from `seed` (the dialer's original rank).
    pub fn new(seed: u64) -> Self {
        DialBackoff {
            base: Duration::from_millis(10),
            // one LCG step ensures rank 0's stream differs from the raw
            // seed progression of rank 1
            lcg: seed
                .wrapping_mul(6_364_136_223_846_793_005)
                .wrapping_add(1_442_695_040_888_963_407),
            attempt: 0,
        }
    }

    /// Next wait: current base plus up to half a base of jitter; the
    /// base then doubles toward the 250 ms cap.
    pub fn next_wait(&mut self) -> Duration {
        self.attempt += 1;
        self.lcg = self
            .lcg
            .wrapping_mul(6_364_136_223_846_793_005)
            .wrapping_add(1_442_695_040_888_963_407);
        let half = (self.base.as_millis() as u64 / 2).max(1);
        let jitter = Duration::from_millis((self.lcg >> 33) % half);
        let wait = self.base + jitter;
        self.base = (self.base * 2).min(Duration::from_millis(250));
        wait
    }
}

/// Dial `addr`, retrying with [`DialBackoff`] until `deadline` — the
/// shared connect path for every rendezvous/epoch dial (the listener
/// may still be binding, or a succession takeover may still be in
/// flight). Each retry is recorded as a
/// [`RecKind::DialRetry`](crate::obs::RecKind::DialRetry) event when a
/// flight recorder is attached. The total retry budget is exactly the
/// caller's deadline: the last sleep is clipped to it and expiry
/// surfaces the underlying connect error.
pub(crate) fn dial_with_backoff(
    addr: &str,
    what: &str,
    deadline: Instant,
    seed: u64,
    flight: Option<&crate::obs::FlightRecorder>,
) -> Result<TcpStream> {
    let mut backoff = DialBackoff::new(seed);
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => {
                let now = Instant::now();
                if now >= deadline {
                    return Err(Error::net(format!(
                        "cannot reach {what} at {addr} within the rendezvous budget: {e}"
                    )));
                }
                let wait = backoff.next_wait().min(deadline - now);
                if let Some(fr) = flight {
                    fr.record(
                        crate::obs::RecKind::DialRetry,
                        0,
                        backoff.attempt,
                        wait.as_millis() as u64,
                    );
                }
                crate::log_debug!(
                    "net",
                    "DialRetry: {what} at {addr} not accepting yet (attempt {}, backing off {:?}): {e}",
                    backoff.attempt,
                    wait
                );
                std::thread::sleep(wait);
            }
        }
    }
}

/// Hub side: bind `coord_addr`, collect one valid [`Frame::Hello`] per
/// rank in `1..n`, then release everyone with [`Frame::Welcome`].
/// Returns the streams rank-indexed (slot 0, the hub itself, is `None`).
pub fn hub_rendezvous(n: usize, cfg: &NetCfg) -> Result<Vec<Option<TcpStream>>> {
    let deadline = Instant::now() + cfg.connect_timeout;
    let listener = bind_with_retry(&cfg.coord_addr, deadline)?;
    hub_rendezvous_on(&listener, n, cfg)
}

/// [`hub_rendezvous`] over an existing listener — the elastic
/// coordinator retains its listener across membership epochs (losing
/// the bound port would strand survivors and joiners alike), so the
/// accept loop must be callable without re-binding.
pub(crate) fn hub_rendezvous_on(
    listener: &TcpListener,
    n: usize,
    cfg: &NetCfg,
) -> Result<Vec<Option<TcpStream>>> {
    listener.set_nonblocking(true)?;
    let deadline = Instant::now() + cfg.connect_timeout;
    let mut peers: Vec<Option<TcpStream>> = (0..n).map(|_| None).collect();
    let mut missing = n - 1;
    while missing > 0 {
        // checked every iteration (not only when accept would block), so
        // a stream of garbage connections cannot extend the rendezvous
        // past its budget
        let remaining = deadline.saturating_duration_since(Instant::now());
        if remaining.is_zero() {
            return Err(rendezvous_timeout(&peers, cfg));
        }
        match listener.accept() {
            Ok((stream, _)) => {
                stream.set_nonblocking(false)?;
                // the Hello read must not eat the whole rendezvous
                // budget: a connection that sends nothing (port scanner,
                // peer that died right after connect) is cut off at the
                // deadline so legitimate ranks can still be seated
                stream.set_read_timeout(Some(
                    remaining
                        .min(cfg.io_timeout)
                        .max(Duration::from_millis(10)),
                ))?;
                stream.set_write_timeout(Some(cfg.io_timeout))?;
                stream.set_nodelay(true)?;
                let mut stream = stream;
                match read_frame(&mut stream) {
                    Ok(Frame::Hello { world, rank }) => {
                        let reject = if world as usize != n {
                            Some(format!(
                                "world size mismatch: claim {world}, hub runs {n}"
                            ))
                        } else if rank == 0 || rank as usize >= n {
                            Some(format!("rank {rank} out of range 1..{n}"))
                        } else if peers[rank as usize].is_some() {
                            Some(format!("rank {rank} already claimed"))
                        } else {
                            None
                        };
                        match reject {
                            Some(reason) => {
                                let _ = write_frame(
                                    &mut stream,
                                    &Frame::Reject { reason },
                                );
                                // dropped; keep waiting for a valid claim
                            }
                            None => {
                                peers[rank as usize] = Some(stream);
                                missing -= 1;
                            }
                        }
                    }
                    Ok(other) => {
                        let _ = write_frame(
                            &mut stream,
                            &Frame::Reject {
                                reason: format!("expected Hello, got {other:?}"),
                            },
                        );
                    }
                    Err(_) => {
                        // undecodable (wrong version / garbage): drop it
                    }
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                // quiet moment: probe seated claimants so one that died
                // after Hello releases its slot instead of burning it
                missing += release_dead_claimants(&mut peers);
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) => return Err(Error::net(format!("hub accept failed: {e}"))),
        }
    }
    for stream in peers.iter_mut().flatten() {
        // seated peers may carry a deadline-clipped read timeout from
        // the Hello phase; reset to the steady-state round deadlines
        set_round_timeouts(stream, cfg)?;
        write_frame(stream, &Frame::Welcome { world: n as u32 })?;
    }
    Ok(peers)
}

/// Probe each seated claimant with a nonblocking 1-byte read: EOF (the
/// claimant died before Welcome), an error, or any premature bytes (a
/// seated claimant must stay silent until Welcome) releases the rank
/// slot so a replacement can claim it. Returns the number of slots
/// released; live claimants are restored to blocking mode untouched.
fn release_dead_claimants(peers: &mut [Option<TcpStream>]) -> usize {
    use std::io::Read;
    let mut released = 0;
    for slot in peers.iter_mut().skip(1) {
        let Some(stream) = slot else { continue };
        let dead = if stream.set_nonblocking(true).is_err() {
            true
        } else {
            let mut probe = [0u8; 1];
            let verdict = match stream.read(&mut probe) {
                Ok(0) => true,
                Ok(_) => true,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => false,
                Err(_) => true,
            };
            if !verdict {
                // restore blocking before the stream is used again
                let _ = stream.set_nonblocking(false);
            }
            verdict
        };
        if dead {
            *slot = None;
            released += 1;
        }
    }
    released
}

fn rendezvous_timeout(peers: &[Option<TcpStream>], cfg: &NetCfg) -> Error {
    let absent: Vec<String> = peers
        .iter()
        .enumerate()
        .skip(1)
        .filter(|(_, s)| s.is_none())
        .map(|(r, _)| r.to_string())
        .collect();
    Error::net(format!(
        "rendezvous timed out after {:?}: still waiting for rank(s) {}",
        cfg.connect_timeout,
        absent.join(", ")
    ))
}

/// Client side: dial the hub (retrying until the deadline — the hub
/// process may not have bound yet), claim `rank`, and wait for
/// [`Frame::Welcome`].
pub fn client_rendezvous(n: usize, rank: usize, cfg: &NetCfg) -> Result<TcpStream> {
    if rank == 0 || rank >= n {
        return Err(Error::invalid(format!(
            "client rank {rank} out of range 1..{n} (rank 0 is the hub)"
        )));
    }
    let deadline = Instant::now() + cfg.connect_timeout;
    let mut stream = dial_with_backoff(&cfg.coord_addr, "hub", deadline, rank as u64, None)?;
    // Welcome may take up to the full rendezvous budget (the hub waits
    // for every rank before releasing anyone)
    stream.set_read_timeout(Some(cfg.connect_timeout))?;
    stream.set_write_timeout(Some(cfg.io_timeout))?;
    stream.set_nodelay(true)?;
    write_frame(
        &mut stream,
        &Frame::Hello {
            world: n as u32,
            rank: rank as u32,
        },
    )?;
    match read_frame(&mut stream)? {
        Frame::Welcome { world } if world as usize == n => {
            set_round_timeouts(&stream, cfg)?;
            Ok(stream)
        }
        Frame::Welcome { world } => Err(Error::protocol(format!(
            "hub confirmed world {world}, expected {n}"
        ))),
        Frame::Reject { reason } => Err(Error::protocol(format!(
            "hub rejected rank {rank}: {reason}"
        ))),
        other => Err(Error::protocol(format!(
            "expected Welcome, got {other:?}"
        ))),
    }
}

/// Pick a free loopback port by binding port 0 and reading it back.
/// There is a small window in which another process could take it, but
/// the single-host launcher hands the address straight to its children.
pub fn free_loopback_addr() -> Result<String> {
    let l = TcpListener::bind("127.0.0.1:0")?;
    let addr = l.local_addr()?;
    Ok(addr.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg(addr: &str) -> NetCfg {
        NetCfg {
            coord_addr: addr.to_string(),
            connect_timeout: Duration::from_secs(10),
            io_timeout: Duration::from_secs(5),
        }
    }

    #[test]
    fn two_rank_rendezvous_completes() {
        let addr = free_loopback_addr().unwrap();
        let cfg = quick_cfg(&addr);
        let cfg2 = cfg.clone();
        let client = std::thread::spawn(move || client_rendezvous(2, 1, &cfg2));
        let peers = hub_rendezvous(2, &cfg).unwrap();
        assert!(peers[0].is_none());
        assert!(peers[1].is_some());
        client.join().unwrap().unwrap();
    }

    #[test]
    fn client_rank_zero_is_rejected_locally() {
        let cfg = quick_cfg("127.0.0.1:1");
        assert!(client_rendezvous(4, 0, &cfg).is_err());
        assert!(client_rendezvous(4, 4, &cfg).is_err());
    }

    #[test]
    fn hub_times_out_when_ranks_missing() {
        let addr = free_loopback_addr().unwrap();
        let cfg = NetCfg {
            coord_addr: addr,
            connect_timeout: Duration::from_millis(200),
            io_timeout: Duration::from_millis(200),
        };
        let err = hub_rendezvous(3, &cfg).unwrap_err().to_string();
        assert!(err.contains("timed out"), "{err}");
        assert!(err.contains('1') && err.contains('2'), "missing ranks listed: {err}");
    }

    #[test]
    fn hub_bind_retries_while_the_port_drains() {
        // hold the coordinator port, release it shortly after the hub
        // starts binding — the rendezvous must ride out the occupancy
        let addr = free_loopback_addr().unwrap();
        let holder = TcpListener::bind(&addr).unwrap();
        let cfg = quick_cfg(&addr);
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(200));
            drop(holder);
        });
        let peers = hub_rendezvous(1, &cfg).unwrap();
        assert!(peers.iter().all(|p| p.is_none()));
        h.join().unwrap();
    }

    #[test]
    fn dead_claimant_releases_its_slot_for_a_replacement() {
        let addr = free_loopback_addr().unwrap();
        let cfg = quick_cfg(&addr);
        // a claimant seats rank 1, then dies before Welcome
        let addr2 = addr.clone();
        let flaky = std::thread::spawn(move || {
            let mut s = loop {
                match TcpStream::connect(&addr2) {
                    Ok(s) => break s,
                    Err(_) => std::thread::sleep(Duration::from_millis(10)),
                }
            };
            write_frame(&mut s, &Frame::Hello { world: 3, rank: 1 }).unwrap();
            std::thread::sleep(Duration::from_millis(100));
            drop(s);
        });
        // a healthy rank 2 arrives late, keeping the hub in its accept
        // loop while the flaky claimant's death is discovered
        let cfg2 = cfg.clone();
        let late = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(300));
            client_rendezvous(3, 2, &cfg2)
        });
        // the replacement re-claims rank 1 — before this fix the hub
        // answered "rank 1 already claimed" forever and timed out
        let cfg3 = cfg.clone();
        let replacement = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(500));
            client_rendezvous(3, 1, &cfg3)
        });
        let peers = hub_rendezvous(3, &cfg).unwrap();
        assert!(peers[1].is_some() && peers[2].is_some());
        flaky.join().unwrap();
        late.join().unwrap().unwrap();
        replacement.join().unwrap().unwrap();
    }

    #[test]
    fn free_addr_is_bindable() {
        let a = free_loopback_addr().unwrap();
        assert!(a.starts_with("127.0.0.1:"));
        // the port is free again after the probe listener dropped
        let _l = TcpListener::bind(&a).unwrap();
    }
}
