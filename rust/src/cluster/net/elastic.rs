//! Epoch re-formation for the socket transports (protocol v5).
//!
//! One process dies and the survivors re-form instead of aborting: that
//! is the whole module. The bootstrap coordinator (original rank 0 — it
//! must outlive the run; chaos tooling refuses to kill it) binds the
//! rendezvous address ONCE, in an [`EpochCoordinator`], and keeps the
//! listener across membership epochs. Epoch 0 is the ordinary star/ring
//! rendezvous run over that retained listener. When a rank dies
//! mid-round, every survivor's collective fails with a typed membership
//! fault ([`Error::PeerLost`](crate::error::Error::PeerLost) /
//! [`Error::Poisoned`](crate::error::Error::Poisoned)); survivors drain
//! the poisoned transport, reconnect to the SAME coordinator address,
//! and claim a seat in epoch `e + 1` with [`Frame::HelloEpoch`]. The
//! coordinator collects claims until every expected survivor has
//! arrived or a grace window expires — non-arrivals are declared dead —
//! then answers each member with [`Frame::WelcomeEpoch`]: its new dense
//! rank, the membership table (original ranks in seat order), the
//! iteration to resume from (the max of the survivors' `next_t`, so no
//! completed work is replayed), and, on the ring, its right neighbor's
//! address.
//!
//! Transport rebuild, not repair: a re-formation constructs a brand-new
//! [`TcpTransport`]/[`RingTransport`] stamped with the new epoch, so
//! data frames need no epoch tag — fresh sockets isolate epochs
//! naturally and the round generation restarts at 0. On the star the
//! `HelloEpoch` rendezvous streams *become* the data-path streams; on
//! the ring members advertise a freshly bound ring listener in their
//! claim and re-link from the `WelcomeEpoch` address table.
//!
//! Late joiners: a restarted rank dials the coordinator with
//! [`Frame::HelloJoin`] at any time. The coordinator's iteration-start
//! probe ([`EpochCoordinator::poll_join`]) parks the claim and reports
//! it; the elastic runner then forces a reform at the boundary, and the
//! parked joiner is seated in the new epoch with a sparsifier state
//! snapshot (the coordinator's own export) riding its `WelcomeEpoch`.

use crate::cluster::net::codec::{read_frame, write_frame, Frame};
use crate::cluster::net::handshake::{
    bind_with_retry, hub_rendezvous_on, set_round_timeouts, NetCfg,
};
use crate::cluster::net::ring::{
    accept_left, coordinate_ring_on, dial_right, host_of, substitute_wildcard_host,
    wildcard_listen_addr, RingTransport,
};
use crate::cluster::net::tcp::TcpTransport;
use crate::cluster::transport::Transport;
use crate::error::{Error, Result};
use std::collections::BTreeMap;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// One formed seat: everything a rank needs to run an epoch.
pub struct EpochSeat {
    /// The epoch this seat belongs to.
    pub epoch: u64,
    /// This rank's new dense rank within the epoch.
    pub rank: usize,
    /// Original ranks of every member, indexed by new dense rank.
    pub world: Vec<u32>,
    /// Iteration the epoch resumes at (0 for the initial formation).
    pub resume_t: u64,
    /// Sparsifier state snapshot (non-empty only for late joiners).
    pub snapshot: Vec<u8>,
    /// The freshly built transport, stamped with `epoch`.
    pub transport: Arc<dyn Transport>,
}

/// A claim accepted outside a reform window, held until the next one.
enum Parked {
    /// A [`Frame::HelloJoin`]: a restarted rank waiting to be seated.
    Joiner {
        orig_rank: u32,
        port: u16,
        stream: TcpStream,
    },
    /// A [`Frame::HelloEpoch`] that raced ahead of the coordinator's
    /// own fault detection.
    Survivor {
        orig_rank: u32,
        next_t: u64,
        port: u16,
        stream: TcpStream,
    },
}

impl Parked {
    fn orig_rank(&self) -> u32 {
        match self {
            Parked::Joiner { orig_rank, .. } | Parked::Survivor { orig_rank, .. } => *orig_rank,
        }
    }
}

/// One member's claim, collected during a reform window.
struct Arrival {
    next_t: u64,
    port: u16,
    stream: TcpStream,
    /// `true` for a fresh joiner (gets the state snapshot), `false`
    /// for a survivor carrying its own state forward.
    fresh: bool,
}

/// The coordinator's decision for one epoch: who sits where, and from
/// which iteration the epoch resumes.
struct EpochPlan {
    /// Original ranks by new dense rank; `world[0] == 0` always.
    world: Vec<u32>,
    resume_t: u64,
    /// Claims by original rank (the coordinator itself is absent).
    members: BTreeMap<u32, Arrival>,
}

/// Original rank 0's persistent half of the elastic protocol: the
/// retained rendezvous listener plus any claims parked between epochs.
pub struct EpochCoordinator {
    listener: TcpListener,
    cfg: NetCfg,
    /// How long a reform waits for missing survivors before declaring
    /// them dead. All survivors fail the same round, so they arrive
    /// within milliseconds of each other; the window only runs out when
    /// someone is genuinely gone.
    grace: Duration,
    parked: Vec<Parked>,
}

impl EpochCoordinator {
    /// Bind the retained rendezvous listener (with the same
    /// retry-with-backoff as the plain hub, closing the free-port
    /// TOCTOU race under `launch`).
    pub fn bind(cfg: &NetCfg, grace: Duration) -> Result<Self> {
        let deadline = Instant::now() + cfg.connect_timeout;
        let listener = bind_with_retry(&cfg.coord_addr, deadline)?;
        Ok(EpochCoordinator {
            listener,
            cfg: cfg.clone(),
            grace,
            parked: Vec::new(),
        })
    }

    /// Epoch 0, star: the ordinary hub rendezvous over the retained
    /// listener; the rendezvous streams become the data-path streams.
    pub fn form_initial_star(&self, n: usize) -> Result<EpochSeat> {
        if n == 0 {
            return Err(Error::invalid("world size must be >= 1"));
        }
        let peers = hub_rendezvous_on(&self.listener, n, &self.cfg)?;
        let tp = TcpTransport::hub_from_parts(n, peers, 0)?;
        Ok(EpochSeat {
            epoch: 0,
            rank: 0,
            world: (0..n as u32).collect(),
            resume_t: 0,
            snapshot: Vec::new(),
            transport: Arc::new(tp),
        })
    }

    /// Epoch 0, ring: the ordinary ring bootstrap over the retained
    /// listener, then dial-right / accept-left as usual.
    pub fn form_initial_ring(&self, n: usize) -> Result<EpochSeat> {
        if n == 0 {
            return Err(Error::invalid("world size must be >= 1"));
        }
        let tp: Arc<dyn Transport> = if n == 1 {
            Arc::new(RingTransport::linkless(1, 0, 0))
        } else {
            let host = host_of(&self.cfg.coord_addr);
            let ring_listener = TcpListener::bind(format!("{host}:0")).map_err(|e| {
                Error::net(format!("rank 0 cannot bind its ring listener on {host}: {e}"))
            })?;
            let my_ring_addr = ring_listener.local_addr()?.to_string();
            let addrs = coordinate_ring_on(&self.listener, n, &self.cfg, &my_ring_addr)?;
            let deadline = Instant::now() + self.cfg.connect_timeout;
            let right = dial_right(&addrs[1], 0, deadline, &self.cfg)?;
            let left = accept_left(&ring_listener, n - 1, deadline, &self.cfg)?;
            Arc::new(RingTransport::assemble(n, 0, right, left, 0)?)
        };
        Ok(EpochSeat {
            epoch: 0,
            rank: 0,
            world: (0..n as u32).collect(),
            resume_t: 0,
            snapshot: Vec::new(),
            transport: tp,
        })
    }

    /// Iteration-start probe: drain the retained listener without
    /// blocking, parking any [`Frame::HelloJoin`] (and any
    /// [`Frame::HelloEpoch`] that raced ahead of this rank's own fault
    /// detection). Returns `true` when a claim is waiting — the caller
    /// must then force a reform at this boundary.
    pub fn poll_join(&mut self) -> Result<bool> {
        self.listener.set_nonblocking(true)?;
        loop {
            match self.listener.accept() {
                Ok((stream, _)) => {
                    stream.set_nonblocking(false)?;
                    // a parked claimant already sent its frame; the
                    // short deadline only guards against garbage dials
                    stream.set_read_timeout(Some(Duration::from_millis(250)))?;
                    stream.set_write_timeout(Some(self.cfg.io_timeout))?;
                    let mut stream = stream;
                    match read_frame(&mut stream) {
                        Ok(Frame::HelloJoin { orig_rank, port }) if orig_rank != 0 => {
                            // a reconnect supersedes an older claim for
                            // the same rank (the old process is gone)
                            self.parked.retain(|p| p.orig_rank() != orig_rank);
                            self.parked.push(Parked::Joiner {
                                orig_rank,
                                port,
                                stream,
                            });
                        }
                        Ok(Frame::HelloEpoch {
                            orig_rank,
                            next_t,
                            port,
                            ..
                        }) if orig_rank != 0 => {
                            self.parked.retain(|p| p.orig_rank() != orig_rank);
                            self.parked.push(Parked::Survivor {
                                orig_rank,
                                next_t,
                                port,
                                stream,
                            });
                        }
                        Ok(other) => {
                            let _ = write_frame(
                                &mut stream,
                                &Frame::Reject {
                                    reason: format!(
                                        "expected HelloJoin between epochs, got {other:?}"
                                    ),
                                },
                            );
                        }
                        Err(_) => {
                            // undecodable garbage: drop it
                        }
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) => return Err(Error::net(format!("join probe accept failed: {e}"))),
            }
        }
        Ok(!self.parked.is_empty())
    }

    /// Collect the claims for `epoch`: parked claims first, then the
    /// retained listener until every expected survivor has arrived or
    /// the grace window expires. `prev_world` is the previous epoch's
    /// membership (original ranks); `known_dead` are ranks the caller
    /// already knows are gone (from the typed fault's attribution), so
    /// a fully attributed failure re-forms without waiting out the
    /// grace window.
    fn collect(
        &mut self,
        epoch: u64,
        prev_world: &[u32],
        known_dead: &[u32],
        my_next_t: u64,
    ) -> Result<EpochPlan> {
        let mut members: BTreeMap<u32, Arrival> = BTreeMap::new();
        for p in self.parked.drain(..) {
            match p {
                Parked::Joiner {
                    orig_rank,
                    port,
                    stream,
                } => {
                    members.insert(
                        orig_rank,
                        Arrival {
                            next_t: 0,
                            port,
                            stream,
                            fresh: true,
                        },
                    );
                }
                Parked::Survivor {
                    orig_rank,
                    next_t,
                    port,
                    stream,
                } => {
                    members.insert(
                        orig_rank,
                        Arrival {
                            next_t,
                            port,
                            stream,
                            fresh: false,
                        },
                    );
                }
            }
        }
        let expected: Vec<u32> = prev_world
            .iter()
            .copied()
            .filter(|&r| r != 0 && !known_dead.contains(&r))
            .collect();
        self.listener.set_nonblocking(true)?;
        let start = Instant::now();
        let grace_deadline = start + self.grace;
        loop {
            if expected.iter().all(|r| members.contains_key(r)) {
                break;
            }
            let remaining = grace_deadline.saturating_duration_since(Instant::now());
            if remaining.is_zero() {
                // whoever is still missing is dead: the survivors form
                // the epoch without them
                break;
            }
            match self.listener.accept() {
                Ok((stream, _)) => {
                    stream.set_nonblocking(false)?;
                    stream.set_read_timeout(Some(
                        remaining.min(self.cfg.io_timeout).max(Duration::from_millis(10)),
                    ))?;
                    stream.set_write_timeout(Some(self.cfg.io_timeout))?;
                    let mut stream = stream;
                    match read_frame(&mut stream) {
                        Ok(Frame::HelloEpoch {
                            epoch: e,
                            orig_rank,
                            next_t,
                            port,
                        }) => {
                            let reject = if e != epoch {
                                Some(format!(
                                    "coordinator is forming epoch {epoch}, claim wants {e}"
                                ))
                            } else if orig_rank == 0 {
                                Some("rank 0 is the coordinator".to_string())
                            } else if members.contains_key(&orig_rank) {
                                Some(format!("rank {orig_rank} already claimed this epoch"))
                            } else {
                                None
                            };
                            match reject {
                                Some(reason) => {
                                    let _ = write_frame(&mut stream, &Frame::Reject { reason });
                                }
                                None => {
                                    members.insert(
                                        orig_rank,
                                        Arrival {
                                            next_t,
                                            port,
                                            stream,
                                            fresh: false,
                                        },
                                    );
                                }
                            }
                        }
                        Ok(Frame::HelloJoin { orig_rank, port }) if orig_rank != 0 => {
                            // a joiner landing inside the window is
                            // seated right away
                            if !members.contains_key(&orig_rank) {
                                members.insert(
                                    orig_rank,
                                    Arrival {
                                        next_t: 0,
                                        port,
                                        stream,
                                        fresh: true,
                                    },
                                );
                            }
                        }
                        Ok(other) => {
                            let _ = write_frame(
                                &mut stream,
                                &Frame::Reject {
                                    reason: format!(
                                        "mid-run epoch reform in progress; got {other:?}"
                                    ),
                                },
                            );
                        }
                        Err(_) => {
                            // undecodable garbage: drop it
                        }
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => return Err(Error::net(format!("reform accept failed: {e}"))),
            }
        }
        let mut world: Vec<u32> = Vec::with_capacity(members.len() + 1);
        world.push(0);
        world.extend(members.keys().copied());
        world.sort_unstable();
        let resume_t = members
            .values()
            .filter(|a| !a.fresh)
            .map(|a| a.next_t)
            .fold(my_next_t, u64::max);
        Ok(EpochPlan {
            world,
            resume_t,
            members,
        })
    }

    /// Re-form the star at `epoch`: collect the claims, seat everyone,
    /// and turn the rendezvous streams into the new star's data-path
    /// streams. `snapshot` is this rank's sparsifier export, forwarded
    /// to joiners only.
    pub fn reform_star(
        &mut self,
        epoch: u64,
        prev_world: &[u32],
        known_dead: &[u32],
        my_next_t: u64,
        snapshot: &[u8],
    ) -> Result<EpochSeat> {
        let plan = self.collect(epoch, prev_world, known_dead, my_next_t)?;
        let n = plan.world.len();
        let mut members = plan.members;
        let mut peers: Vec<Option<TcpStream>> = (0..n).map(|_| None).collect();
        for (new_rank, &orig) in plan.world.iter().enumerate() {
            if orig == 0 {
                continue;
            }
            let mut arr = members
                .remove(&orig)
                .expect("world was built from the member set");
            write_frame(
                &mut arr.stream,
                &Frame::WelcomeEpoch {
                    epoch,
                    rank: new_rank as u32,
                    world: plan.world.clone(),
                    resume_t: plan.resume_t,
                    right_addr: String::new(),
                    snapshot: if arr.fresh {
                        snapshot.to_vec()
                    } else {
                        Vec::new()
                    },
                },
            )?;
            set_round_timeouts(&arr.stream, &self.cfg)?;
            peers[new_rank] = Some(arr.stream);
        }
        let tp = TcpTransport::hub_from_parts(n, peers, epoch)?;
        Ok(EpochSeat {
            epoch,
            rank: 0,
            world: plan.world,
            resume_t: plan.resume_t,
            snapshot: Vec::new(),
            transport: Arc::new(tp),
        })
    }

    /// Re-form the ring at `epoch`: collect the claims, advertise the
    /// new neighbor table, drop the rendezvous streams, and re-link.
    pub fn reform_ring(
        &mut self,
        epoch: u64,
        prev_world: &[u32],
        known_dead: &[u32],
        my_next_t: u64,
        snapshot: &[u8],
    ) -> Result<EpochSeat> {
        let plan = self.collect(epoch, prev_world, known_dead, my_next_t)?;
        let n = plan.world.len();
        let mut members = plan.members;
        let tp: Arc<dyn Transport> = if n == 1 {
            Arc::new(RingTransport::linkless(1, 0, epoch))
        } else {
            let host = host_of(&self.cfg.coord_addr);
            let ring_listener = TcpListener::bind(format!("{host}:0")).map_err(|e| {
                Error::net(format!("rank 0 cannot bind its ring listener on {host}: {e}"))
            })?;
            let my_ring_addr = ring_listener.local_addr()?.to_string();
            // rank-indexed ring addresses: the coordinator's fresh
            // listener plus each member's advertised port at the IP it
            // dialed in from
            let mut addrs: Vec<String> = Vec::with_capacity(n);
            for &orig in plan.world.iter() {
                if orig == 0 {
                    addrs.push(my_ring_addr.clone());
                } else {
                    let arr = members
                        .get(&orig)
                        .expect("world was built from the member set");
                    let ip = arr.stream.peer_addr()?.ip();
                    addrs.push(SocketAddr::new(ip, arr.port).to_string());
                }
            }
            for (new_rank, &orig) in plan.world.iter().enumerate() {
                if orig == 0 {
                    continue;
                }
                let mut arr = members
                    .remove(&orig)
                    .expect("world was built from the member set");
                write_frame(
                    &mut arr.stream,
                    &Frame::WelcomeEpoch {
                        epoch,
                        rank: new_rank as u32,
                        world: plan.world.clone(),
                        resume_t: plan.resume_t,
                        right_addr: addrs[(new_rank + 1) % n].clone(),
                        snapshot: if arr.fresh {
                            snapshot.to_vec()
                        } else {
                            Vec::new()
                        },
                    },
                )?;
                // rendezvous stream drops here; the data path is the
                // fresh ring links only
            }
            let deadline = Instant::now() + self.cfg.connect_timeout;
            let right = dial_right(&addrs[1], 0, deadline, &self.cfg)?;
            let left = accept_left(&ring_listener, n - 1, deadline, &self.cfg)?;
            Arc::new(RingTransport::assemble(n, 0, right, left, epoch)?)
        };
        Ok(EpochSeat {
            epoch,
            rank: 0,
            world: plan.world,
            resume_t: plan.resume_t,
            snapshot: Vec::new(),
            transport: tp,
        })
    }
}

/// Dial the retained coordinator address, retrying until the connect
/// timeout (between windows a joiner's connect can be refused while the
/// backlog churns).
fn dial_coord(cfg: &NetCfg) -> Result<TcpStream> {
    let deadline = Instant::now() + cfg.connect_timeout;
    loop {
        match TcpStream::connect(&cfg.coord_addr) {
            Ok(s) => return Ok(s),
            Err(e) => {
                if Instant::now() >= deadline {
                    return Err(Error::net(format!(
                        "cannot reach the epoch coordinator at {} within {:?}: {e}",
                        cfg.coord_addr, cfg.connect_timeout
                    )));
                }
                std::thread::sleep(Duration::from_millis(25));
            }
        }
    }
}

/// The fields of a received [`Frame::WelcomeEpoch`].
struct Welcome {
    epoch: u64,
    rank: usize,
    world: Vec<u32>,
    resume_t: u64,
    right_addr: String,
    snapshot: Vec<u8>,
}

/// Read the coordinator's answer; `want_epoch` is checked for survivors
/// (who know which epoch they are claiming) and skipped for joiners
/// (who take whatever epoch forms next).
fn expect_welcome(stream: &mut TcpStream, want_epoch: Option<u64>) -> Result<Welcome> {
    match read_frame(stream)? {
        Frame::WelcomeEpoch {
            epoch,
            rank,
            world,
            resume_t,
            right_addr,
            snapshot,
        } => {
            if let Some(want) = want_epoch {
                if epoch != want {
                    return Err(Error::protocol(format!(
                        "coordinator formed epoch {epoch}, this rank claimed {want}"
                    )));
                }
            }
            Ok(Welcome {
                epoch,
                rank: rank as usize,
                world,
                resume_t,
                right_addr,
                snapshot,
            })
        }
        Frame::Reject { reason } => Err(Error::protocol(format!(
            "coordinator rejected the epoch claim: {reason}"
        ))),
        other => Err(Error::protocol(format!(
            "expected WelcomeEpoch, got {other:?}"
        ))),
    }
}

/// Survivor side of a star re-formation: claim a seat in `epoch` and
/// keep the rendezvous stream as the new data-path stream to the hub.
pub fn reform_star_client(
    cfg: &NetCfg,
    epoch: u64,
    orig_rank: u32,
    next_t: u64,
) -> Result<EpochSeat> {
    let mut stream = dial_coord(cfg)?;
    // the Welcome may take the whole reform budget (the coordinator
    // waits out the grace window for slower survivors)
    stream.set_read_timeout(Some(cfg.connect_timeout))?;
    stream.set_write_timeout(Some(cfg.io_timeout))?;
    write_frame(
        &mut stream,
        &Frame::HelloEpoch {
            epoch,
            orig_rank,
            next_t,
            port: 0,
        },
    )?;
    let w = expect_welcome(&mut stream, Some(epoch))?;
    set_round_timeouts(&stream, cfg)?;
    let n = w.world.len();
    let tp = TcpTransport::client_from_parts(n, w.rank, stream, epoch)?;
    Ok(EpochSeat {
        epoch: w.epoch,
        rank: w.rank,
        world: w.world,
        resume_t: w.resume_t,
        snapshot: w.snapshot,
        transport: Arc::new(tp),
    })
}

/// Survivor side of a ring re-formation: bind a fresh ring listener,
/// claim a seat in `epoch`, then re-link from the advertised table.
pub fn reform_ring_client(
    cfg: &NetCfg,
    epoch: u64,
    orig_rank: u32,
    next_t: u64,
) -> Result<EpochSeat> {
    let ring_listener = TcpListener::bind(wildcard_listen_addr(host_of(&cfg.coord_addr)))
        .map_err(|e| Error::net(format!("cannot bind a reform ring listener: {e}")))?;
    let port = ring_listener.local_addr()?.port();
    let mut coord = dial_coord(cfg)?;
    coord.set_read_timeout(Some(cfg.connect_timeout))?;
    coord.set_write_timeout(Some(cfg.io_timeout))?;
    write_frame(
        &mut coord,
        &Frame::HelloEpoch {
            epoch,
            orig_rank,
            next_t,
            port,
        },
    )?;
    let w = expect_welcome(&mut coord, Some(epoch))?;
    drop(coord);
    ring_links_from_welcome(cfg, &ring_listener, w)
}

/// Joiner side, star: ask to be seated at the next boundary; the
/// returned seat carries the coordinator's sparsifier snapshot.
pub fn join_star(cfg: &NetCfg, orig_rank: u32) -> Result<EpochSeat> {
    let mut stream = dial_coord(cfg)?;
    // the Welcome arrives at the next epoch boundary, one iteration +
    // grace + reform away at worst
    stream.set_read_timeout(Some(cfg.connect_timeout))?;
    stream.set_write_timeout(Some(cfg.io_timeout))?;
    write_frame(&mut stream, &Frame::HelloJoin { orig_rank, port: 0 })?;
    let w = expect_welcome(&mut stream, None)?;
    set_round_timeouts(&stream, cfg)?;
    let n = w.world.len();
    let epoch = w.epoch;
    let tp = TcpTransport::client_from_parts(n, w.rank, stream, epoch)?;
    Ok(EpochSeat {
        epoch,
        rank: w.rank,
        world: w.world,
        resume_t: w.resume_t,
        snapshot: w.snapshot,
        transport: Arc::new(tp),
    })
}

/// Joiner side, ring: bind a fresh ring listener, ask to be seated at
/// the next boundary, then re-link from the advertised table.
pub fn join_ring(cfg: &NetCfg, orig_rank: u32) -> Result<EpochSeat> {
    let ring_listener = TcpListener::bind(wildcard_listen_addr(host_of(&cfg.coord_addr)))
        .map_err(|e| Error::net(format!("cannot bind a rejoin ring listener: {e}")))?;
    let port = ring_listener.local_addr()?.port();
    let mut coord = dial_coord(cfg)?;
    coord.set_read_timeout(Some(cfg.connect_timeout))?;
    coord.set_write_timeout(Some(cfg.io_timeout))?;
    write_frame(&mut coord, &Frame::HelloJoin { orig_rank, port })?;
    let w = expect_welcome(&mut coord, None)?;
    drop(coord);
    ring_links_from_welcome(cfg, &ring_listener, w)
}

/// Shared ring tail: dial the advertised right neighbor, accept the
/// left one, and assemble the new-epoch transport.
fn ring_links_from_welcome(
    cfg: &NetCfg,
    ring_listener: &TcpListener,
    w: Welcome,
) -> Result<EpochSeat> {
    let n = w.world.len();
    let epoch = w.epoch;
    // the coordinator's own ring address may carry a wildcard bind
    // host; dial the host this rank reached the coordinator on
    let right_addr = substitute_wildcard_host(w.right_addr, host_of(&cfg.coord_addr));
    let deadline = Instant::now() + cfg.connect_timeout;
    let right = dial_right(&right_addr, w.rank, deadline, cfg)?;
    let left = accept_left(ring_listener, w.rank - 1, deadline, cfg)?;
    let tp = RingTransport::assemble(n, w.rank, right, left, epoch)?;
    Ok(EpochSeat {
        epoch,
        rank: w.rank,
        world: w.world,
        resume_t: w.resume_t,
        snapshot: w.snapshot,
        transport: Arc::new(tp),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::net::handshake::free_loopback_addr;
    use crate::cluster::transport::Endpoint;

    fn cfg(addr: &str) -> NetCfg {
        NetCfg {
            coord_addr: addr.to_string(),
            connect_timeout: Duration::from_secs(20),
            io_timeout: Duration::from_secs(10),
        }
    }

    /// Drive one allgather round over a seat and check the board is
    /// rank-indexed over the seat's world.
    fn one_round(seat: &EpochSeat) {
        let ep = Endpoint::new(seat.rank, seat.transport.as_ref());
        let got = ep.allgather_f64(seat.world[seat.rank] as f64).unwrap();
        let want: Vec<f64> = seat.world.iter().map(|&r| r as f64).collect();
        assert_eq!(got, want, "epoch {} rank {}", seat.epoch, seat.rank);
    }

    /// Full star lifecycle: form 3 ranks at epoch 0, kill rank 1,
    /// re-form at epoch 1 with the survivors, then seat rank 1 back at
    /// epoch 2 via HelloJoin with the snapshot riding its Welcome.
    #[test]
    fn star_reforms_after_a_death_and_seats_a_rejoiner() {
        let addr = free_loopback_addr().unwrap();
        let c = cfg(&addr);
        let c1 = c.clone();
        let c2 = c.clone();
        // gate h2's epoch-2 claim until the joiner's claim has been
        // parked, so the coordinator's poll_join loop deterministically
        // sees the HelloJoin first
        let (tx, rx) = std::sync::mpsc::channel::<()>();
        let h1 = std::thread::spawn(move || {
            let tp = TcpTransport::client(3, 1, &c1).unwrap();
            // rank 1 "dies": its transport simply drops
            drop(tp);
        });
        let h2 = std::thread::spawn(move || {
            let tp = TcpTransport::client(3, 2, &c2).unwrap();
            drop(tp);
            // survive into epoch 1 (claim arrives while the
            // coordinator is still collecting)
            let seat = reform_star_client(&c2, 1, 2, 7).unwrap();
            assert_eq!(seat.world, vec![0, 2]);
            assert_eq!(seat.rank, 1, "dense re-rank");
            assert_eq!(seat.resume_t, 7, "resume at the max survivor next_t");
            assert!(seat.snapshot.is_empty(), "survivors carry their own state");
            one_round(&seat);
            // epoch 2: the restarted rank 1 is back
            rx.recv().unwrap();
            let seat = reform_star_client(&c2, 2, 2, 9).unwrap();
            assert_eq!(seat.world, vec![0, 1, 2]);
            assert_eq!(seat.rank, 2);
            one_round(&seat);
        });
        let mut coord = EpochCoordinator::bind(&c, Duration::from_millis(800)).unwrap();
        let seat0 = coord.form_initial_star(3).unwrap();
        assert_eq!(seat0.epoch, 0);
        assert_eq!(seat0.world, vec![0, 1, 2]);
        h1.join().unwrap();
        // rank 1 is known dead (the typed fault attributed it), so the
        // reform does not wait out the grace window for it
        let seat1 = coord.reform_star(1, &[0, 1, 2], &[1], 5, b"state-e1").unwrap();
        assert_eq!(seat1.epoch, 1);
        assert_eq!(seat1.world, vec![0, 2]);
        assert_eq!(seat1.resume_t, 7);
        assert_eq!(seat1.transport.epoch(), 1);
        one_round(&seat1);
        // the dead rank restarts and asks back in
        let c3 = c.clone();
        let h3 = std::thread::spawn(move || {
            let seat = join_star(&c3, 1).unwrap();
            assert_eq!(seat.epoch, 2);
            assert_eq!(seat.world, vec![0, 1, 2]);
            assert_eq!(seat.rank, 1);
            assert_eq!(seat.resume_t, 9);
            assert_eq!(seat.snapshot, b"state-e2", "joiner gets the snapshot");
            one_round(&seat);
        });
        // wait for the join claim to land, as the runner's probe would
        let deadline = Instant::now() + Duration::from_secs(10);
        while !coord.poll_join().unwrap() {
            assert!(Instant::now() < deadline, "join claim never arrived");
            std::thread::sleep(Duration::from_millis(10));
        }
        tx.send(()).unwrap();
        let seat2 = coord.reform_star(2, &[0, 2], &[], 9, b"state-e2").unwrap();
        assert_eq!(seat2.world, vec![0, 1, 2]);
        one_round(&seat2);
        h2.join().unwrap();
        h3.join().unwrap();
    }

    /// Ring re-formation: 3 ranks at epoch 0, rank 2 dies, survivors
    /// re-link as a 2-ring at epoch 1 over fresh listeners.
    #[test]
    fn ring_reforms_with_fresh_links() {
        let addr = free_loopback_addr().unwrap();
        let c = cfg(&addr);
        let c1 = c.clone();
        let c2 = c.clone();
        let h1 = std::thread::spawn(move || {
            let tp = RingTransport::client(3, 1, &c1).unwrap();
            drop(tp);
            let seat = reform_ring_client(&c1, 1, 1, 4).unwrap();
            assert_eq!(seat.world, vec![0, 1]);
            assert_eq!(seat.rank, 1);
            assert_eq!(seat.resume_t, 4);
            assert_eq!(seat.transport.epoch(), 1);
            one_round(&seat);
        });
        let h2 = std::thread::spawn(move || {
            // rank 2 "dies" after the initial formation
            let tp = RingTransport::client(3, 2, &c2).unwrap();
            drop(tp);
        });
        let mut coord = EpochCoordinator::bind(&c, Duration::from_millis(800)).unwrap();
        let seat0 = coord.form_initial_ring(3).unwrap();
        assert_eq!(seat0.transport.epoch(), 0);
        h2.join().unwrap();
        let seat1 = coord.reform_ring(1, &[0, 1, 2], &[2], 3, &[]).unwrap();
        assert_eq!(seat1.epoch, 1);
        assert_eq!(seat1.world, vec![0, 1]);
        assert_eq!(seat1.resume_t, 4);
        one_round(&seat1);
        h1.join().unwrap();
    }

    /// A lone survivor forms a single-rank epoch once the grace window
    /// runs out on everyone else.
    #[test]
    fn grace_expiry_forms_a_singleton_epoch() {
        let addr = free_loopback_addr().unwrap();
        let c = cfg(&addr);
        let mut coord = EpochCoordinator::bind(&c, Duration::from_millis(200)).unwrap();
        // no initial formation needed: reform only consults prev_world
        let seat = coord.reform_ring(1, &[0, 1], &[], 6, &[]).unwrap();
        assert_eq!(seat.world, vec![0]);
        assert_eq!(seat.resume_t, 6);
        assert_eq!(seat.rank, 0);
        one_round(&seat);
        // the star path degenerates the same way
        let seat = coord.reform_star(2, &[0], &[], 8, &[]).unwrap();
        assert_eq!(seat.world, vec![0]);
        assert_eq!(seat.transport.epoch(), 2);
        one_round(&seat);
    }
}
