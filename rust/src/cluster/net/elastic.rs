//! Epoch re-formation for the socket transports (protocol v6).
//!
//! One process dies and the survivors re-form instead of aborting: that
//! is the whole module. The current coordinator (original rank 0 at
//! first; any member after a succession) binds a rendezvous listener
//! ONCE, in an [`EpochCoordinator`], and keeps it across membership
//! epochs. Epoch 0 under the elastic path is formed through the same
//! [`Frame::HelloEpoch`]/[`Frame::WelcomeEpoch`] exchange as every
//! re-formation, so the succession table below rides every seating.
//! When a rank dies mid-round, every survivor's collective fails with a
//! typed membership fault
//! ([`Error::PeerLost`](crate::error::Error::PeerLost) /
//! [`Error::Poisoned`](crate::error::Error::Poisoned)); survivors drain
//! the poisoned transport, re-rendezvous (see below), and claim a seat
//! in epoch `e + 1` with [`Frame::HelloEpoch`]. The coordinator
//! collects claims until every expected survivor has arrived or a grace
//! window expires — non-arrivals are declared dead — then answers each
//! member with [`Frame::WelcomeEpoch`]: its new dense rank, the
//! membership table (original ranks in seat order), the iteration to
//! resume from (the max of the survivors' `next_t`, so no completed
//! work is replayed), on the ring its right neighbor's address, and the
//! coordinator succession table.
//!
//! Coordinator succession (protocol v6): the coordinator is no longer a
//! fixed process. Every member pre-binds one *standby* listener for the
//! life of its process and advertises the port in each claim; each
//! `WelcomeEpoch` carries the seat-ordered succession table — the
//! coordinator's own rendezvous address at seat 0, every other member's
//! standby address at its seat. After a fault, survivors walk that
//! table in order with [`reform_via_succession`]: each entry is dialed
//! with bounded exponential backoff, a live entry's (pre-bound) standby
//! listener accepts the claim and the survivor simply waits to be
//! seated, while a dead entry refuses the dial and the walk moves on.
//! A survivor that reaches its own seat with every earlier entry dead
//! returns [`ReformOutcome::Promote`]: it is the lowest-ranked live
//! member, so it — deterministically and uniquely — converts its
//! standby listener into the new [`EpochCoordinator`]
//! ([`EpochCoordinator::promote`]) and forms the epoch from the
//! membership snapshot it already holds. A dead rank 0 therefore costs
//! one epoch, not the run.
//!
//! Transport rebuild, not repair: a re-formation constructs a brand-new
//! [`TcpTransport`]/[`RingTransport`] stamped with the new epoch, so
//! data frames need no epoch tag — fresh sockets isolate epochs
//! naturally and the round generation restarts at 0. On the star the
//! `HelloEpoch` rendezvous streams *become* the data-path streams; on
//! the ring members advertise a freshly bound ring listener in their
//! claim and re-link from the `WelcomeEpoch` address table.
//!
//! Late joiners: a restarted rank dials the coordinator with
//! [`Frame::HelloJoin`] at any time. The coordinator's iteration-start
//! probe ([`EpochCoordinator::poll_join`]) parks the claim and reports
//! it; the elastic runner then forces a reform at the boundary, and the
//! parked joiner is seated in the new epoch with a sparsifier state
//! snapshot (the coordinator's own export) riding its `WelcomeEpoch`.

use crate::cluster::net::codec::{read_frame, write_frame, Frame};
use crate::cluster::net::handshake::{
    bind_with_retry, dial_with_backoff, set_round_timeouts, DialBackoff, NetCfg,
};
use crate::cluster::net::ring::{
    accept_left, dial_right, host_of, substitute_wildcard_host,
    wildcard_listen_addr, RingTransport,
};
use crate::cluster::net::tcp::TcpTransport;
use crate::cluster::transport::Transport;
use crate::error::{Error, Result};
use std::collections::BTreeMap;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// One formed seat: everything a rank needs to run an epoch.
pub struct EpochSeat {
    /// The epoch this seat belongs to.
    pub epoch: u64,
    /// This rank's new dense rank within the epoch.
    pub rank: usize,
    /// Original ranks of every member, indexed by new dense rank.
    pub world: Vec<u32>,
    /// Iteration the epoch resumes at (0 for the initial formation).
    pub resume_t: u64,
    /// Sparsifier state snapshot (non-empty only for late joiners).
    pub snapshot: Vec<u8>,
    /// Coordinator succession table, seat-indexed and aligned with
    /// `world`: the address the member at each seat would coordinate
    /// the next re-rendezvous on ("" = no standby advertised). Walked
    /// by [`reform_via_succession`] when the coordinator itself dies.
    pub succession: Vec<String>,
    /// The freshly built transport, stamped with `epoch`.
    pub transport: Arc<dyn Transport>,
}

/// A claim accepted outside a reform window, held until the next one.
enum Parked {
    /// A [`Frame::HelloJoin`]: a restarted rank waiting to be seated.
    Joiner {
        orig_rank: u32,
        port: u16,
        standby_port: u16,
        stream: TcpStream,
    },
    /// A [`Frame::HelloEpoch`] that raced ahead of the coordinator's
    /// own fault detection.
    Survivor {
        orig_rank: u32,
        next_t: u64,
        port: u16,
        standby_port: u16,
        stream: TcpStream,
    },
}

impl Parked {
    fn orig_rank(&self) -> u32 {
        match self {
            Parked::Joiner { orig_rank, .. } | Parked::Survivor { orig_rank, .. } => *orig_rank,
        }
    }
}

/// One member's claim, collected during a reform window.
struct Arrival {
    next_t: u64,
    port: u16,
    /// Advertised standby listener port (0 = none), paired with the
    /// claim stream's source IP to build the succession table.
    standby_port: u16,
    stream: TcpStream,
    /// `true` for a fresh joiner (gets the state snapshot), `false`
    /// for a survivor carrying its own state forward.
    fresh: bool,
}

/// The coordinator's decision for one epoch: who sits where, and from
/// which iteration the epoch resumes.
struct EpochPlan {
    /// Original ranks by new dense rank; seat 0 is always the current
    /// coordinator (the lowest live original rank).
    world: Vec<u32>,
    resume_t: u64,
    /// Claims by original rank (the coordinator itself is absent).
    members: BTreeMap<u32, Arrival>,
}

/// The current coordinator's persistent half of the elastic protocol:
/// the retained rendezvous listener plus any claims parked between
/// epochs. Originally rank 0's; after a succession, the promoted
/// member's activated standby listener.
pub struct EpochCoordinator {
    listener: TcpListener,
    cfg: NetCfg,
    /// This coordinator's original rank (0 until a succession).
    my_orig: u32,
    /// The address members dial this coordinator's `listener` on — its
    /// own entry in the succession tables it publishes.
    advertised_addr: String,
    /// How long a reform waits for missing survivors before declaring
    /// them dead. All survivors fail the same round, so they arrive
    /// within milliseconds of each other; the window only runs out when
    /// someone is genuinely gone.
    grace: Duration,
    parked: Vec<Parked>,
}

impl EpochCoordinator {
    /// Bind the retained rendezvous listener (with the same
    /// retry-with-backoff as the plain hub, closing the free-port
    /// TOCTOU race under `launch`).
    pub fn bind(cfg: &NetCfg, grace: Duration) -> Result<Self> {
        let deadline = Instant::now() + cfg.connect_timeout;
        let listener = bind_with_retry(&cfg.coord_addr, deadline)?;
        Ok(EpochCoordinator {
            listener,
            cfg: cfg.clone(),
            my_orig: 0,
            advertised_addr: cfg.coord_addr.clone(),
            grace,
            parked: Vec::new(),
        })
    }

    /// Succession takeover: a promoted member's pre-bound standby
    /// listener becomes the new epoch rendezvous. `advertised_addr` is
    /// this member's own entry from the succession table it was seated
    /// with — the address every other survivor walks to, and the entry
    /// published for seat 0 of the tables this coordinator forms.
    pub fn promote(
        standby: TcpListener,
        my_orig: u32,
        advertised_addr: String,
        cfg: &NetCfg,
        grace: Duration,
    ) -> Self {
        EpochCoordinator {
            listener: standby,
            cfg: cfg.clone(),
            my_orig,
            advertised_addr,
            grace,
            parked: Vec::new(),
        }
    }

    /// This coordinator's original rank.
    pub fn orig_rank(&self) -> u32 {
        self.my_orig
    }

    /// Host this coordinator binds fresh (ring) listeners on: the host
    /// members reach it at, falling back to the bootstrap rendezvous
    /// host while the advertised address carries a wildcard.
    fn bind_host(&self) -> &str {
        let h = host_of(&self.advertised_addr);
        if h == "0.0.0.0" || h == "[::]" {
            host_of(&self.cfg.coord_addr)
        } else {
            h
        }
    }

    /// The seat-ordered succession table for `world`: this
    /// coordinator's own rendezvous address at its seat, each member's
    /// standby address (claim-stream source IP + advertised port) at
    /// theirs.
    fn succession_for(&self, world: &[u32], members: &BTreeMap<u32, Arrival>) -> Result<Vec<String>> {
        world
            .iter()
            .map(|&orig| {
                if orig == self.my_orig {
                    return Ok(self.advertised_addr.clone());
                }
                let arr = members
                    .get(&orig)
                    .expect("world was built from the member set");
                if arr.standby_port == 0 {
                    return Ok(String::new());
                }
                let ip = arr.stream.peer_addr()?.ip();
                Ok(SocketAddr::new(ip, arr.standby_port).to_string())
            })
            .collect()
    }

    /// Epoch 0, star: the epoch rendezvous over the retained listener
    /// with a complete world required — every rank in `1..n` must claim
    /// before the connect timeout. Runs the same
    /// `HelloEpoch`/`WelcomeEpoch` exchange as every re-formation so
    /// the succession table rides the initial seating too.
    pub fn form_initial_star(&mut self, n: usize) -> Result<EpochSeat> {
        if n == 0 {
            return Err(Error::invalid("world size must be >= 1"));
        }
        let world0: Vec<u32> = (0..n as u32).collect();
        self.star_epoch(0, &world0, &[], 0, &[], true)
    }

    /// Epoch 0, ring: like [`EpochCoordinator::form_initial_star`] but
    /// the members re-link as a ring from the advertised table.
    pub fn form_initial_ring(&mut self, n: usize) -> Result<EpochSeat> {
        if n == 0 {
            return Err(Error::invalid("world size must be >= 1"));
        }
        let world0: Vec<u32> = (0..n as u32).collect();
        self.ring_epoch(0, &world0, &[], 0, &[], true)
    }

    /// Iteration-start probe: drain the retained listener without
    /// blocking, parking any [`Frame::HelloJoin`] (and any
    /// [`Frame::HelloEpoch`] that raced ahead of this rank's own fault
    /// detection). Returns `true` when a claim is waiting — the caller
    /// must then force a reform at this boundary.
    pub fn poll_join(&mut self) -> Result<bool> {
        self.listener.set_nonblocking(true)?;
        loop {
            match self.listener.accept() {
                Ok((stream, _)) => {
                    stream.set_nonblocking(false)?;
                    // a parked claimant already sent its frame; the
                    // short deadline only guards against garbage dials
                    stream.set_read_timeout(Some(Duration::from_millis(250)))?;
                    stream.set_write_timeout(Some(self.cfg.io_timeout))?;
                    let mut stream = stream;
                    match read_frame(&mut stream) {
                        Ok(Frame::HelloJoin {
                            orig_rank,
                            port,
                            standby_port,
                        }) if orig_rank > self.my_orig => {
                            // a reconnect supersedes an older claim for
                            // the same rank (the old process is gone)
                            self.parked.retain(|p| p.orig_rank() != orig_rank);
                            self.parked.push(Parked::Joiner {
                                orig_rank,
                                port,
                                standby_port,
                                stream,
                            });
                        }
                        Ok(Frame::HelloEpoch {
                            orig_rank,
                            next_t,
                            port,
                            standby_port,
                            ..
                        }) if orig_rank > self.my_orig => {
                            self.parked.retain(|p| p.orig_rank() != orig_rank);
                            self.parked.push(Parked::Survivor {
                                orig_rank,
                                next_t,
                                port,
                                standby_port,
                                stream,
                            });
                        }
                        Ok(other) => {
                            let _ = write_frame(
                                &mut stream,
                                &Frame::Reject {
                                    reason: format!(
                                        "expected HelloJoin between epochs, got {other:?}"
                                    ),
                                },
                            );
                        }
                        Err(_) => {
                            // undecodable garbage: drop it
                        }
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) => return Err(Error::net(format!("join probe accept failed: {e}"))),
            }
        }
        Ok(!self.parked.is_empty())
    }

    /// Collect the claims for `epoch`: parked claims first, then the
    /// retained listener until every expected survivor has arrived or
    /// the window expires. `prev_world` is the previous epoch's
    /// membership (original ranks); `known_dead` are ranks the caller
    /// already knows are gone (from the typed fault's attribution), so
    /// a fully attributed failure re-forms without waiting out the
    /// grace window. `initial` switches the window semantics: the
    /// initial formation waits the full connect timeout, requires every
    /// expected rank, and admits no one else; a reform waits only the
    /// grace window and seats whoever shows up.
    fn collect(
        &mut self,
        epoch: u64,
        prev_world: &[u32],
        known_dead: &[u32],
        my_next_t: u64,
        initial: bool,
    ) -> Result<EpochPlan> {
        let mut members: BTreeMap<u32, Arrival> = BTreeMap::new();
        for p in self.parked.drain(..) {
            match p {
                Parked::Joiner {
                    orig_rank,
                    port,
                    standby_port,
                    stream,
                } => {
                    members.insert(
                        orig_rank,
                        Arrival {
                            next_t: 0,
                            port,
                            standby_port,
                            stream,
                            fresh: true,
                        },
                    );
                }
                Parked::Survivor {
                    orig_rank,
                    next_t,
                    port,
                    standby_port,
                    stream,
                } => {
                    members.insert(
                        orig_rank,
                        Arrival {
                            next_t,
                            port,
                            standby_port,
                            stream,
                            fresh: false,
                        },
                    );
                }
            }
        }
        let expected: Vec<u32> = prev_world
            .iter()
            .copied()
            .filter(|&r| r != self.my_orig && !known_dead.contains(&r))
            .collect();
        self.listener.set_nonblocking(true)?;
        let window = if initial { self.cfg.connect_timeout } else { self.grace };
        let deadline = Instant::now() + window;
        loop {
            if expected.iter().all(|r| members.contains_key(r)) {
                break;
            }
            let remaining = deadline.saturating_duration_since(Instant::now());
            if remaining.is_zero() {
                if initial {
                    let absent: Vec<String> = expected
                        .iter()
                        .filter(|r| !members.contains_key(r))
                        .map(|r| r.to_string())
                        .collect();
                    return Err(Error::net(format!(
                        "epoch rendezvous timed out after {window:?}: still waiting \
                         for rank(s) {}",
                        absent.join(", ")
                    )));
                }
                // whoever is still missing is dead: the survivors form
                // the epoch without them
                break;
            }
            match self.listener.accept() {
                Ok((stream, _)) => {
                    stream.set_nonblocking(false)?;
                    stream.set_read_timeout(Some(
                        remaining.min(self.cfg.io_timeout).max(Duration::from_millis(10)),
                    ))?;
                    stream.set_write_timeout(Some(self.cfg.io_timeout))?;
                    let mut stream = stream;
                    match read_frame(&mut stream) {
                        Ok(Frame::HelloEpoch {
                            epoch: e,
                            orig_rank,
                            next_t,
                            port,
                            standby_port,
                        }) => {
                            let reject = if e != epoch {
                                Some(format!(
                                    "coordinator is forming epoch {epoch}, claim wants {e}"
                                ))
                            } else if orig_rank == self.my_orig {
                                Some(format!("rank {orig_rank} is the coordinator"))
                            } else if orig_rank < self.my_orig {
                                // seat 0 must stay the lowest original
                                // rank: a lower rank coming back after a
                                // succession would displace the sitting
                                // coordinator
                                Some(format!(
                                    "rank {orig_rank} precedes coordinator rank {} in the \
                                     succession order",
                                    self.my_orig
                                ))
                            } else if initial && !expected.contains(&orig_rank) {
                                Some(format!(
                                    "rank {orig_rank} is not part of the initial world"
                                ))
                            } else if members.contains_key(&orig_rank) {
                                Some(format!("rank {orig_rank} already claimed this epoch"))
                            } else {
                                None
                            };
                            match reject {
                                Some(reason) => {
                                    let _ = write_frame(&mut stream, &Frame::Reject { reason });
                                }
                                None => {
                                    members.insert(
                                        orig_rank,
                                        Arrival {
                                            next_t,
                                            port,
                                            standby_port,
                                            stream,
                                            fresh: false,
                                        },
                                    );
                                }
                            }
                        }
                        Ok(Frame::HelloJoin {
                            orig_rank,
                            port,
                            standby_port,
                        }) if orig_rank > self.my_orig && !initial => {
                            // a joiner landing inside the window is
                            // seated right away
                            if !members.contains_key(&orig_rank) {
                                members.insert(
                                    orig_rank,
                                    Arrival {
                                        next_t: 0,
                                        port,
                                        standby_port,
                                        stream,
                                        fresh: true,
                                    },
                                );
                            }
                        }
                        Ok(other) => {
                            let _ = write_frame(
                                &mut stream,
                                &Frame::Reject {
                                    reason: format!(
                                        "mid-run epoch reform in progress; got {other:?}"
                                    ),
                                },
                            );
                        }
                        Err(_) => {
                            // undecodable garbage: drop it
                        }
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => return Err(Error::net(format!("reform accept failed: {e}"))),
            }
        }
        let mut world: Vec<u32> = Vec::with_capacity(members.len() + 1);
        world.push(self.my_orig);
        world.extend(members.keys().copied());
        world.sort_unstable();
        let resume_t = members
            .values()
            .filter(|a| !a.fresh)
            .map(|a| a.next_t)
            .fold(my_next_t, u64::max);
        Ok(EpochPlan {
            world,
            resume_t,
            members,
        })
    }

    /// Re-form the star at `epoch`: collect the claims, seat everyone,
    /// and turn the rendezvous streams into the new star's data-path
    /// streams. `snapshot` is this rank's sparsifier export, forwarded
    /// to joiners only.
    pub fn reform_star(
        &mut self,
        epoch: u64,
        prev_world: &[u32],
        known_dead: &[u32],
        my_next_t: u64,
        snapshot: &[u8],
    ) -> Result<EpochSeat> {
        self.star_epoch(epoch, prev_world, known_dead, my_next_t, snapshot, false)
    }

    /// Re-form the ring at `epoch`: collect the claims, advertise the
    /// new neighbor table, drop the rendezvous streams, and re-link.
    pub fn reform_ring(
        &mut self,
        epoch: u64,
        prev_world: &[u32],
        known_dead: &[u32],
        my_next_t: u64,
        snapshot: &[u8],
    ) -> Result<EpochSeat> {
        self.ring_epoch(epoch, prev_world, known_dead, my_next_t, snapshot, false)
    }

    fn star_epoch(
        &mut self,
        epoch: u64,
        prev_world: &[u32],
        known_dead: &[u32],
        my_next_t: u64,
        snapshot: &[u8],
        initial: bool,
    ) -> Result<EpochSeat> {
        let plan = self.collect(epoch, prev_world, known_dead, my_next_t, initial)?;
        let n = plan.world.len();
        let succession = self.succession_for(&plan.world, &plan.members)?;
        let my_seat = self.my_seat(&plan.world);
        let mut members = plan.members;
        let mut peers: Vec<Option<TcpStream>> = (0..n).map(|_| None).collect();
        for (new_rank, &orig) in plan.world.iter().enumerate() {
            if orig == self.my_orig {
                continue;
            }
            let mut arr = members
                .remove(&orig)
                .expect("world was built from the member set");
            write_frame(
                &mut arr.stream,
                &Frame::WelcomeEpoch {
                    epoch,
                    rank: new_rank as u32,
                    world: plan.world.clone(),
                    resume_t: plan.resume_t,
                    right_addr: String::new(),
                    snapshot: if arr.fresh {
                        snapshot.to_vec()
                    } else {
                        Vec::new()
                    },
                    succession: succession.clone(),
                },
            )?;
            set_round_timeouts(&arr.stream, &self.cfg)?;
            peers[new_rank] = Some(arr.stream);
        }
        let tp = TcpTransport::hub_from_parts(n, peers, epoch)?;
        Ok(EpochSeat {
            epoch,
            rank: my_seat,
            world: plan.world,
            resume_t: plan.resume_t,
            snapshot: Vec::new(),
            succession,
            transport: Arc::new(tp),
        })
    }

    fn ring_epoch(
        &mut self,
        epoch: u64,
        prev_world: &[u32],
        known_dead: &[u32],
        my_next_t: u64,
        snapshot: &[u8],
        initial: bool,
    ) -> Result<EpochSeat> {
        let plan = self.collect(epoch, prev_world, known_dead, my_next_t, initial)?;
        let n = plan.world.len();
        let succession = self.succession_for(&plan.world, &plan.members)?;
        let my_seat = self.my_seat(&plan.world);
        let mut members = plan.members;
        let tp: Arc<dyn Transport> = if n == 1 {
            Arc::new(RingTransport::linkless(1, 0, epoch))
        } else {
            let host = self.bind_host();
            let ring_listener = TcpListener::bind(format!("{host}:0")).map_err(|e| {
                Error::net(format!(
                    "the coordinator cannot bind its ring listener on {host}: {e}"
                ))
            })?;
            let my_ring_addr = ring_listener.local_addr()?.to_string();
            // rank-indexed ring addresses: the coordinator's fresh
            // listener plus each member's advertised port at the IP it
            // dialed in from
            let mut addrs: Vec<String> = Vec::with_capacity(n);
            for &orig in plan.world.iter() {
                if orig == self.my_orig {
                    addrs.push(my_ring_addr.clone());
                } else {
                    let arr = members
                        .get(&orig)
                        .expect("world was built from the member set");
                    let ip = arr.stream.peer_addr()?.ip();
                    addrs.push(SocketAddr::new(ip, arr.port).to_string());
                }
            }
            for (new_rank, &orig) in plan.world.iter().enumerate() {
                if orig == self.my_orig {
                    continue;
                }
                let mut arr = members
                    .remove(&orig)
                    .expect("world was built from the member set");
                write_frame(
                    &mut arr.stream,
                    &Frame::WelcomeEpoch {
                        epoch,
                        rank: new_rank as u32,
                        world: plan.world.clone(),
                        resume_t: plan.resume_t,
                        right_addr: addrs[(new_rank + 1) % n].clone(),
                        snapshot: if arr.fresh {
                            snapshot.to_vec()
                        } else {
                            Vec::new()
                        },
                        succession: succession.clone(),
                    },
                )?;
                // rendezvous stream drops here; the data path is the
                // fresh ring links only
            }
            let deadline = Instant::now() + self.cfg.connect_timeout;
            let right = dial_right(&addrs[(my_seat + 1) % n], my_seat, deadline, &self.cfg)?;
            let left = accept_left(&ring_listener, (my_seat + n - 1) % n, deadline, &self.cfg)?;
            Arc::new(RingTransport::assemble(n, my_seat, right, left, epoch)?)
        };
        Ok(EpochSeat {
            epoch,
            rank: my_seat,
            world: plan.world,
            resume_t: plan.resume_t,
            snapshot: Vec::new(),
            succession,
            transport: tp,
        })
    }

    /// This coordinator's dense seat within `world` — seat 0, since the
    /// coordinator is always the lowest live original rank.
    fn my_seat(&self, world: &[u32]) -> usize {
        world
            .iter()
            .position(|&r| r == self.my_orig)
            .expect("the coordinator sits in its own world")
    }
}

/// Pre-bind a member's standby listener: the socket it would
/// coordinate the next epoch on if promoted. Bound once per process at
/// seating time and kept for the process lifetime — a *live* member's
/// succession entry therefore always accepts (a survivor's claim just
/// waits in the backlog until the member notices the fault and
/// promotes), while a refused dial reliably means the member is dead.
/// That asymmetry is what makes the succession walk's promotion
/// decision deterministic and split-brain free.
pub fn bind_standby(cfg: &NetCfg) -> Result<(TcpListener, u16)> {
    let listener = TcpListener::bind(wildcard_listen_addr(host_of(&cfg.coord_addr)))
        .map_err(|e| Error::net(format!("cannot bind a standby listener: {e}")))?;
    let port = listener.local_addr()?.port();
    Ok((listener, port))
}

/// Dial an epoch coordinator address with the shared backoff train.
fn dial_coord_at(addr: &str, cfg: &NetCfg, orig_rank: u32) -> Result<TcpStream> {
    let deadline = Instant::now() + cfg.connect_timeout;
    dial_with_backoff(
        addr,
        "the epoch coordinator",
        deadline,
        orig_rank as u64,
        None,
    )
}

/// The fields of a received [`Frame::WelcomeEpoch`].
struct Welcome {
    epoch: u64,
    rank: usize,
    world: Vec<u32>,
    resume_t: u64,
    right_addr: String,
    snapshot: Vec<u8>,
    succession: Vec<String>,
}

/// Read the coordinator's answer; `want_epoch` is checked for survivors
/// (who know which epoch they are claiming) and skipped for joiners
/// (who take whatever epoch forms next).
fn expect_welcome(stream: &mut TcpStream, want_epoch: Option<u64>) -> Result<Welcome> {
    match read_frame(stream)? {
        Frame::WelcomeEpoch {
            epoch,
            rank,
            world,
            resume_t,
            right_addr,
            snapshot,
            succession,
        } => {
            if let Some(want) = want_epoch {
                if epoch != want {
                    return Err(Error::protocol(format!(
                        "coordinator formed epoch {epoch}, this rank claimed {want}"
                    )));
                }
            }
            Ok(Welcome {
                epoch,
                rank: rank as usize,
                world,
                resume_t,
                right_addr,
                snapshot,
                succession,
            })
        }
        Frame::Reject { reason } => Err(Error::protocol(format!(
            "coordinator rejected the epoch claim: {reason}"
        ))),
        other => Err(Error::protocol(format!(
            "expected WelcomeEpoch, got {other:?}"
        ))),
    }
}

/// Send `hello` over a connected coordinator stream, await the seating,
/// and keep the stream as the new star's data path. `welcome_wait`
/// bounds the wait for the Welcome (the coordinator may wait out the
/// grace window, or — on a succession — first have to notice the fault
/// itself).
fn await_star_seat(
    mut stream: TcpStream,
    cfg: &NetCfg,
    hello: &Frame,
    want_epoch: Option<u64>,
    welcome_wait: Duration,
) -> Result<EpochSeat> {
    stream.set_read_timeout(Some(welcome_wait.max(Duration::from_millis(10))))?;
    stream.set_write_timeout(Some(cfg.io_timeout))?;
    write_frame(&mut stream, hello)?;
    let w = expect_welcome(&mut stream, want_epoch)?;
    set_round_timeouts(&stream, cfg)?;
    let n = w.world.len();
    let epoch = w.epoch;
    let tp = TcpTransport::client_from_parts(n, w.rank, stream, epoch)?;
    Ok(EpochSeat {
        epoch,
        rank: w.rank,
        world: w.world,
        resume_t: w.resume_t,
        snapshot: w.snapshot,
        succession: w.succession,
        transport: Arc::new(tp),
    })
}

/// Ring twin of [`await_star_seat`]: the coordinator stream only
/// carries the seating; the data path is re-linked from the advertised
/// neighbor table afterwards. `dialed_addr` is the address the
/// coordinator was actually reached at — after a succession that is no
/// longer `cfg.coord_addr`, and wildcard bind hosts in the neighbor
/// table must be substituted with it.
fn await_ring_seat(
    mut coord: TcpStream,
    cfg: &NetCfg,
    dialed_addr: &str,
    ring_listener: &TcpListener,
    hello: &Frame,
    want_epoch: Option<u64>,
    welcome_wait: Duration,
) -> Result<EpochSeat> {
    coord.set_read_timeout(Some(welcome_wait.max(Duration::from_millis(10))))?;
    coord.set_write_timeout(Some(cfg.io_timeout))?;
    write_frame(&mut coord, hello)?;
    let w = expect_welcome(&mut coord, want_epoch)?;
    drop(coord);
    ring_links_from_welcome(cfg, dialed_addr, ring_listener, w)
}

/// Survivor side of a star re-formation against a *live* coordinator:
/// claim a seat in `epoch` at the bootstrap rendezvous address and keep
/// the stream as the new data-path stream to the hub. (When the
/// coordinator itself may be the casualty, use
/// [`reform_via_succession`] instead.)
pub fn reform_star_client(
    cfg: &NetCfg,
    epoch: u64,
    orig_rank: u32,
    next_t: u64,
    standby_port: u16,
) -> Result<EpochSeat> {
    let stream = dial_coord_at(&cfg.coord_addr, cfg, orig_rank)?;
    let hello = Frame::HelloEpoch {
        epoch,
        orig_rank,
        next_t,
        port: 0,
        standby_port,
    };
    await_star_seat(stream, cfg, &hello, Some(epoch), cfg.connect_timeout)
}

/// Survivor side of a ring re-formation against a *live* coordinator:
/// bind a fresh ring listener, claim a seat in `epoch`, then re-link
/// from the advertised table.
pub fn reform_ring_client(
    cfg: &NetCfg,
    epoch: u64,
    orig_rank: u32,
    next_t: u64,
    standby_port: u16,
) -> Result<EpochSeat> {
    let ring_listener = TcpListener::bind(wildcard_listen_addr(host_of(&cfg.coord_addr)))
        .map_err(|e| Error::net(format!("cannot bind a reform ring listener: {e}")))?;
    let port = ring_listener.local_addr()?.port();
    let coord = dial_coord_at(&cfg.coord_addr, cfg, orig_rank)?;
    let hello = Frame::HelloEpoch {
        epoch,
        orig_rank,
        next_t,
        port,
        standby_port,
    };
    await_ring_seat(
        coord,
        cfg,
        &cfg.coord_addr,
        &ring_listener,
        &hello,
        Some(epoch),
        cfg.connect_timeout,
    )
}

/// The outcome of walking the succession table after a fault.
pub enum ReformOutcome {
    /// Seated by a (possibly freshly promoted) coordinator.
    Seated(EpochSeat),
    /// Every candidate ahead of this member in the succession order is
    /// dead: this member is the lowest surviving original rank and must
    /// promote its standby listener into the new [`EpochCoordinator`].
    Promote,
}

/// Walk the succession table to claim a seat in `epoch` after a fault
/// that may have taken the coordinator itself.
///
/// Entries are tried in seat order. A dead entry refuses the dial (its
/// listener died with its process) and the walk moves on; a live entry
/// accepts — its standby is pre-bound — and the claim simply waits
/// until that member either seats us (it is, or becomes, the
/// coordinator) or the budget runs out. The first pass skips the entry
/// the fault was attributed to (`lost`); later passes dial it too, so
/// a misattribution costs one refused connect, not a seat. When every
/// candidate ahead of `orig_rank` is unreachable the walk returns
/// [`ReformOutcome::Promote`]: by the pre-bound-listener invariant they
/// are all dead, so this member is the lowest survivor and exactly one
/// member ever promotes. All dials ride [`DialBackoff`]'s jittered
/// train and the whole walk is bounded by the connect timeout.
#[allow(clippy::too_many_arguments)]
pub fn reform_via_succession(
    cfg: &NetCfg,
    ring: bool,
    epoch: u64,
    orig_rank: u32,
    next_t: u64,
    standby_port: u16,
    world: &[u32],
    succession: &[String],
    lost: Option<u32>,
    flight: Option<&crate::obs::FlightRecorder>,
) -> Result<ReformOutcome> {
    let my_seat = world
        .iter()
        .position(|&r| r == orig_rank)
        .ok_or_else(|| {
            Error::invalid(format!(
                "rank {orig_rank} is not part of the world it is re-forming from"
            ))
        })?;
    if succession.len() != world.len() {
        return Err(Error::protocol(format!(
            "succession table covers {} seats, world has {}",
            succession.len(),
            world.len()
        )));
    }
    let (ring_listener, ring_port) = if ring {
        let l = TcpListener::bind(wildcard_listen_addr(host_of(&cfg.coord_addr)))
            .map_err(|e| Error::net(format!("cannot bind a reform ring listener: {e}")))?;
        let p = l.local_addr()?.port();
        (Some(l), p)
    } else {
        (None, 0)
    };
    let hello = Frame::HelloEpoch {
        epoch,
        orig_rank,
        next_t,
        port: ring_port,
        standby_port,
    };
    let deadline = Instant::now() + cfg.connect_timeout;
    let mut backoff = DialBackoff::new(orig_rank as u64);
    let mut skip_lost = true;
    loop {
        let mut live_predecessor = false;
        let mut skipped = false;
        for seat in 0..my_seat {
            let entry = &succession[seat];
            if entry.is_empty() {
                // no standby advertised: not a coordinator candidate
                continue;
            }
            if skip_lost && lost == Some(world[seat]) {
                // the fault named this member; don't burn a dial on it
                // (a dead host's connect can hang through SYN retries)
                // while a live candidate may be waiting further on
                skipped = true;
                continue;
            }
            let addr =
                substitute_wildcard_host(entry.clone(), host_of(&cfg.coord_addr));
            let stream = match TcpStream::connect(&addr) {
                Ok(s) => s,
                Err(e) => {
                    crate::log_debug!(
                        "elastic",
                        "succession seat {seat} (rank {}) refused at {addr}: {e}",
                        world[seat]
                    );
                    continue;
                }
            };
            live_predecessor = true;
            let wait = deadline.saturating_duration_since(Instant::now());
            let claimed = match &ring_listener {
                Some(l) => {
                    await_ring_seat(stream, cfg, &addr, l, &hello, Some(epoch), wait)
                }
                None => await_star_seat(stream, cfg, &hello, Some(epoch), wait),
            };
            match claimed {
                Ok(seat) => return Ok(ReformOutcome::Seated(seat)),
                Err(e) if Instant::now() < deadline => {
                    // the candidate died under us (e.g. a second kill
                    // racing the reform): keep walking — whoever is
                    // next in line will take over
                    crate::log_debug!(
                        "elastic",
                        "claim against succession seat {seat} (rank {}) failed ({e}); \
                         walking on",
                        world[seat]
                    );
                }
                Err(e) => return Err(e),
            }
        }
        if !live_predecessor {
            if skipped {
                // every dialed predecessor is dead, but the attributed
                // one was skipped: promotion must rest on an observed
                // refusal, not on attribution alone — run a confirming
                // pass that dials everyone
                skip_lost = false;
                continue;
            }
            if succession[my_seat].is_empty() {
                return Err(Error::net(
                    "every coordinator candidate ahead in the succession order is \
                     dead and this member advertised no standby listener",
                ));
            }
            return Ok(ReformOutcome::Promote);
        }
        let remaining = deadline.saturating_duration_since(Instant::now());
        if remaining.is_zero() {
            return Err(Error::net(format!(
                "no succession candidate seated rank {orig_rank} for epoch {epoch} \
                 within {:?}",
                cfg.connect_timeout
            )));
        }
        let wait = backoff.next_wait().min(remaining);
        if let Some(fr) = flight {
            fr.record(
                crate::obs::RecKind::DialRetry,
                0,
                backoff.attempt,
                wait.as_millis() as u64,
            );
        }
        skip_lost = false;
        std::thread::sleep(wait);
    }
}

/// Joiner side, star: ask to be seated at the next boundary; the
/// returned seat carries the coordinator's sparsifier snapshot.
pub fn join_star(cfg: &NetCfg, orig_rank: u32, standby_port: u16) -> Result<EpochSeat> {
    let stream = dial_coord_at(&cfg.coord_addr, cfg, orig_rank)?;
    // the Welcome arrives at the next epoch boundary, one iteration +
    // grace + reform away at worst
    let hello = Frame::HelloJoin {
        orig_rank,
        port: 0,
        standby_port,
    };
    await_star_seat(stream, cfg, &hello, None, cfg.connect_timeout)
}

/// Joiner side, ring: bind a fresh ring listener, ask to be seated at
/// the next boundary, then re-link from the advertised table.
pub fn join_ring(cfg: &NetCfg, orig_rank: u32, standby_port: u16) -> Result<EpochSeat> {
    let ring_listener = TcpListener::bind(wildcard_listen_addr(host_of(&cfg.coord_addr)))
        .map_err(|e| Error::net(format!("cannot bind a rejoin ring listener: {e}")))?;
    let port = ring_listener.local_addr()?.port();
    let coord = dial_coord_at(&cfg.coord_addr, cfg, orig_rank)?;
    let hello = Frame::HelloJoin {
        orig_rank,
        port,
        standby_port,
    };
    await_ring_seat(
        coord,
        cfg,
        &cfg.coord_addr,
        &ring_listener,
        &hello,
        None,
        cfg.connect_timeout,
    )
}

/// Shared ring tail: dial the advertised right neighbor, accept the
/// left one, and assemble the new-epoch transport. `dialed_addr` is
/// where this rank actually reached the coordinator — the substitute
/// host for any wildcard bind address in the neighbor table.
fn ring_links_from_welcome(
    cfg: &NetCfg,
    dialed_addr: &str,
    ring_listener: &TcpListener,
    w: Welcome,
) -> Result<EpochSeat> {
    let n = w.world.len();
    let epoch = w.epoch;
    // the coordinator's own ring address may carry a wildcard bind
    // host; dial the host this rank reached the coordinator on
    let right_addr = substitute_wildcard_host(w.right_addr, host_of(dialed_addr));
    let deadline = Instant::now() + cfg.connect_timeout;
    let right = dial_right(&right_addr, w.rank, deadline, cfg)?;
    let left = accept_left(ring_listener, w.rank - 1, deadline, cfg)?;
    let tp = RingTransport::assemble(n, w.rank, right, left, epoch)?;
    Ok(EpochSeat {
        epoch,
        rank: w.rank,
        world: w.world,
        resume_t: w.resume_t,
        snapshot: w.snapshot,
        succession: w.succession,
        transport: Arc::new(tp),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::net::handshake::free_loopback_addr;
    use crate::cluster::transport::Endpoint;

    fn cfg(addr: &str) -> NetCfg {
        NetCfg {
            coord_addr: addr.to_string(),
            connect_timeout: Duration::from_secs(20),
            io_timeout: Duration::from_secs(10),
        }
    }

    /// Drive one allgather round over a seat and check the board is
    /// rank-indexed over the seat's world.
    fn one_round(seat: &EpochSeat) {
        let ep = Endpoint::new(seat.rank, seat.transport.as_ref());
        let got = ep.allgather_f64(seat.world[seat.rank] as f64).unwrap();
        let want: Vec<f64> = seat.world.iter().map(|&r| r as f64).collect();
        assert_eq!(got, want, "epoch {} rank {}", seat.epoch, seat.rank);
    }

    /// Full star lifecycle: form 3 ranks at epoch 0, kill rank 1,
    /// re-form at epoch 1 with the survivors, then seat rank 1 back at
    /// epoch 2 via HelloJoin with the snapshot riding its Welcome.
    #[test]
    fn star_reforms_after_a_death_and_seats_a_rejoiner() {
        let addr = free_loopback_addr().unwrap();
        let c = cfg(&addr);
        let c1 = c.clone();
        let c2 = c.clone();
        // gate h2's epoch-2 claim until the joiner's claim has been
        // parked, so the coordinator's poll_join loop deterministically
        // sees the HelloJoin first
        let (tx, rx) = std::sync::mpsc::channel::<()>();
        let h1 = std::thread::spawn(move || {
            let seat = reform_star_client(&c1, 0, 1, 0, 0).unwrap();
            assert_eq!(seat.world, vec![0, 1, 2]);
            one_round(&seat);
            // rank 1 "dies": its transport simply drops
            drop(seat);
        });
        let h2 = std::thread::spawn(move || {
            let (_standby, sb_port) = bind_standby(&c2).unwrap();
            let seat = reform_star_client(&c2, 0, 2, 0, sb_port).unwrap();
            assert_eq!(
                seat.succession[0], c2.coord_addr,
                "seat 0 of the table is the rendezvous address"
            );
            assert_eq!(seat.succession[1], "", "rank 1 advertised no standby");
            assert!(
                seat.succession[2].ends_with(&format!(":{sb_port}")),
                "rank 2's entry carries its standby port: {:?}",
                seat.succession
            );
            one_round(&seat);
            drop(seat);
            // survive into epoch 1 (claim arrives while the
            // coordinator is still collecting)
            let seat = reform_star_client(&c2, 1, 2, 7, sb_port).unwrap();
            assert_eq!(seat.world, vec![0, 2]);
            assert_eq!(seat.rank, 1, "dense re-rank");
            assert_eq!(seat.resume_t, 7, "resume at the max survivor next_t");
            assert!(seat.snapshot.is_empty(), "survivors carry their own state");
            one_round(&seat);
            // epoch 2: the restarted rank 1 is back
            rx.recv().unwrap();
            let seat = reform_star_client(&c2, 2, 2, 9, sb_port).unwrap();
            assert_eq!(seat.world, vec![0, 1, 2]);
            assert_eq!(seat.rank, 2);
            one_round(&seat);
        });
        let mut coord = EpochCoordinator::bind(&c, Duration::from_millis(800)).unwrap();
        let seat0 = coord.form_initial_star(3).unwrap();
        assert_eq!(seat0.epoch, 0);
        assert_eq!(seat0.world, vec![0, 1, 2]);
        one_round(&seat0);
        h1.join().unwrap();
        // rank 1 is known dead (the typed fault attributed it), so the
        // reform does not wait out the grace window for it
        let seat1 = coord.reform_star(1, &[0, 1, 2], &[1], 5, b"state-e1").unwrap();
        assert_eq!(seat1.epoch, 1);
        assert_eq!(seat1.world, vec![0, 2]);
        assert_eq!(seat1.resume_t, 7);
        assert_eq!(seat1.transport.epoch(), 1);
        one_round(&seat1);
        // the dead rank restarts and asks back in
        let c3 = c.clone();
        let h3 = std::thread::spawn(move || {
            let seat = join_star(&c3, 1, 0).unwrap();
            assert_eq!(seat.epoch, 2);
            assert_eq!(seat.world, vec![0, 1, 2]);
            assert_eq!(seat.rank, 1);
            assert_eq!(seat.resume_t, 9);
            assert_eq!(seat.snapshot, b"state-e2", "joiner gets the snapshot");
            one_round(&seat);
        });
        // wait for the join claim to land, as the runner's probe would
        let deadline = Instant::now() + Duration::from_secs(10);
        while !coord.poll_join().unwrap() {
            assert!(Instant::now() < deadline, "join claim never arrived");
            std::thread::sleep(Duration::from_millis(10));
        }
        tx.send(()).unwrap();
        let seat2 = coord.reform_star(2, &[0, 2], &[], 9, b"state-e2").unwrap();
        assert_eq!(seat2.world, vec![0, 1, 2]);
        one_round(&seat2);
        h2.join().unwrap();
        h3.join().unwrap();
    }

    /// Ring re-formation: 3 ranks at epoch 0, rank 2 dies, survivors
    /// re-link as a 2-ring at epoch 1 over fresh listeners.
    #[test]
    fn ring_reforms_with_fresh_links() {
        let addr = free_loopback_addr().unwrap();
        let c = cfg(&addr);
        let c1 = c.clone();
        let c2 = c.clone();
        let h1 = std::thread::spawn(move || {
            let seat = reform_ring_client(&c1, 0, 1, 0, 0).unwrap();
            one_round(&seat);
            drop(seat);
            let seat = reform_ring_client(&c1, 1, 1, 4, 0).unwrap();
            assert_eq!(seat.world, vec![0, 1]);
            assert_eq!(seat.rank, 1);
            assert_eq!(seat.resume_t, 4);
            assert_eq!(seat.transport.epoch(), 1);
            one_round(&seat);
        });
        let h2 = std::thread::spawn(move || {
            // rank 2 "dies" after the initial formation
            let seat = reform_ring_client(&c2, 0, 2, 0, 0).unwrap();
            one_round(&seat);
            drop(seat);
        });
        let mut coord = EpochCoordinator::bind(&c, Duration::from_millis(800)).unwrap();
        let seat0 = coord.form_initial_ring(3).unwrap();
        assert_eq!(seat0.transport.epoch(), 0);
        one_round(&seat0);
        h2.join().unwrap();
        let seat1 = coord.reform_ring(1, &[0, 1, 2], &[2], 3, &[]).unwrap();
        assert_eq!(seat1.epoch, 1);
        assert_eq!(seat1.world, vec![0, 1]);
        assert_eq!(seat1.resume_t, 4);
        one_round(&seat1);
        h1.join().unwrap();
    }

    /// Coordinator death: rank 0 forms epoch 0 and dies; rank 1 walks
    /// the succession table, finds every predecessor gone, promotes its
    /// pre-bound standby listener, and seats rank 2 — which walked the
    /// same table and parked its claim at rank 1's standby.
    #[test]
    fn succession_promotes_the_lowest_survivor_after_the_coordinator_dies() {
        let addr = free_loopback_addr().unwrap();
        let c = cfg(&addr);
        let c1 = c.clone();
        let c2 = c.clone();
        let h1 = std::thread::spawn(move || {
            let (standby, sb_port) = bind_standby(&c1).unwrap();
            let seat0 = reform_star_client(&c1, 0, 1, 0, sb_port).unwrap();
            let world0 = seat0.world.clone();
            let succ0 = seat0.succession.clone();
            one_round(&seat0);
            drop(seat0);
            // the fault is attributed to rank 0: walk the table
            let outcome = reform_via_succession(
                &c1, false, 1, 1, 5, sb_port, &world0, &succ0, Some(0), None,
            )
            .unwrap();
            assert!(
                matches!(outcome, ReformOutcome::Promote),
                "rank 1 is the lowest survivor"
            );
            let mut coord = EpochCoordinator::promote(
                standby,
                1,
                succ0[1].clone(),
                &c1,
                Duration::from_millis(800),
            );
            assert_eq!(coord.orig_rank(), 1);
            let seat1 = coord.reform_star(1, &world0, &[0], 5, &[]).unwrap();
            assert_eq!(seat1.world, vec![1, 2]);
            assert_eq!(seat1.rank, 0, "the promoted coordinator sits at seat 0");
            assert_eq!(
                seat1.succession[0], succ0[1],
                "the new table leads with the promoted member's standby"
            );
            one_round(&seat1);
        });
        let h2 = std::thread::spawn(move || {
            let (_standby, sb_port) = bind_standby(&c2).unwrap();
            let seat0 = reform_star_client(&c2, 0, 2, 0, sb_port).unwrap();
            let world0 = seat0.world.clone();
            let succ0 = seat0.succession.clone();
            one_round(&seat0);
            drop(seat0);
            let outcome = reform_via_succession(
                &c2, false, 1, 2, 5, sb_port, &world0, &succ0, Some(0), None,
            )
            .unwrap();
            let seat1 = match outcome {
                ReformOutcome::Seated(s) => s,
                ReformOutcome::Promote => panic!("rank 1 precedes rank 2 in the succession"),
            };
            assert_eq!(seat1.epoch, 1);
            assert_eq!(seat1.world, vec![1, 2]);
            assert_eq!(seat1.rank, 1);
            assert_eq!(seat1.resume_t, 5);
            one_round(&seat1);
        });
        let mut coord = EpochCoordinator::bind(&c, Duration::from_millis(800)).unwrap();
        let seat0 = coord.form_initial_star(3).unwrap();
        assert_eq!(seat0.succession[0], addr);
        one_round(&seat0);
        // rank 0 dies: seat and rendezvous listener both close
        drop(seat0);
        drop(coord);
        h1.join().unwrap();
        h2.join().unwrap();
    }

    /// A lone survivor forms a single-rank epoch once the grace window
    /// runs out on everyone else.
    #[test]
    fn grace_expiry_forms_a_singleton_epoch() {
        let addr = free_loopback_addr().unwrap();
        let c = cfg(&addr);
        let mut coord = EpochCoordinator::bind(&c, Duration::from_millis(200)).unwrap();
        // no initial formation needed: reform only consults prev_world
        let seat = coord.reform_ring(1, &[0, 1], &[], 6, &[]).unwrap();
        assert_eq!(seat.world, vec![0]);
        assert_eq!(seat.resume_t, 6);
        assert_eq!(seat.rank, 0);
        one_round(&seat);
        // the star path degenerates the same way
        let seat = coord.reform_star(2, &[0], &[], 8, &[]).unwrap();
        assert_eq!(seat.world, vec![0]);
        assert_eq!(seat.transport.epoch(), 2);
        one_round(&seat);
    }
}
