//! [`RingTransport`] — the chunked-ring [`Transport`] impl over TCP.
//!
//! Topology: a directed ring. Every rank owns two sockets — one dialed
//! to its *right* neighbor `(rank + 1) % n` (send side) and one accepted
//! from its *left* neighbor `(rank + n - 1) % n` (receive side). An
//! all-gather is the textbook ring algorithm: each rank starts with its
//! own message in board slot `rank` and runs `n - 1` steps; at step `s`
//! it forwards slot `(rank - s) mod n` to the right and receives slot
//! `(rank - s - 1) mod n` from the left, so after `n - 1` hops every
//! rank holds the full rank-indexed board. Per round, every *link*
//! carries exactly `n - 1` messages — no node carries more traffic than
//! any other, unlike the [`TcpTransport`] hub-star, whose hub link
//! carries the other `n - 1` ranks' contributions in *and* `n - 1`
//! whole-board fan-outs out (the gradient build-up pathology of the
//! paper, replayed at the harness layer; see
//! [`CostModel::allgather_star`] for the modeled asymmetry).
//!
//! Rendezvous: rank 0 doubles as the *coordinator* (bootstrap only — it
//! is not on the data path after setup). Every rank binds its own ring
//! listener first; ranks `1..n` dial the coordinator address and claim
//! their rank with [`Frame::HelloRing`] (which also advertises their
//! ring listener's port). Once every slot is claimed, the coordinator
//! answers each rank with [`Frame::WelcomeRing`] carrying its right
//! neighbor's `host:port` and drops the bootstrap connections. Each
//! rank then dials its right neighbor (identifying itself with
//! [`Frame::RingLink`]) and accepts its left neighbor on its own
//! listener, validating the claimed rank. All waits are bounded by
//! [`NetCfg::connect_timeout`].
//!
//! Deadlock freedom: within a step, rank 0 *receives before sending*
//! while every other rank sends first. A cycle of ranks all blocked in
//! `write` (possible when payloads exceed the socket buffers) therefore
//! always has one rank draining its left link, which unblocks its left
//! neighbor's write, and so on around the ring — progress is guaranteed
//! for arbitrarily large messages, at worst serializing one hop chain.
//! Split-phase rounds keep the same ordering: at start every
//! non-coordinator rank writes its step-0 chunk eagerly (the overlap
//! window is genuine transfer time), while rank 0 defers even that
//! send to finish — it is the ring's designated drainer, so a cluster
//! fully parked in its overlap windows still cannot write-deadlock.
//!
//! Steady-state reuse mirrors the PR 3 zero-copy work: one persistent
//! encode and one decode buffer per transport (no per-frame `Vec`), the
//! slot vector is retained across rounds, and the published board slab
//! is recycled once the caller has dropped its clone — the remaining
//! per-round allocations are the socket-decoded payloads themselves,
//! exactly as on the star transport. Failure semantics are shared with
//! [`TcpTransport`]: generation-stamped frames turn divergence into
//! typed [`Error::Protocol`]s, every read/write carries the
//! [`NetCfg::io_timeout`] deadline, and [`Transport::abort`] poisons the
//! transport — best-effort [`Frame::Abort`] to both neighbors (stamped
//! with the failed rank and round generation, so the poison's origin
//! survives the trip around the ring as a typed
//! [`Error::PeerLost`](crate::error::Error::PeerLost)), then socket
//! shutdown, so a broken ring surfaces errors on every rank instead of
//! hanging.
//!
//! The reduce-scatter → all-gather collective runs the true chunked
//! ring schedule over the same two links: phase 1 forwards each index
//! chunk as a [`Frame::Shard`], every rank adding its own contribution
//! in place before re-encoding, so after `n - 1` hops rank r holds its
//! own fully reduced shard summed in the canonical ring order; phase 2
//! all-gathers the n reduced shards in `n - 1` more hops. Per link and
//! per round that is `2(n-1)/n · V` bytes instead of the all-gather's
//! `(n-1) · V` ([`CostModel::rsag_link_bytes_ring`]) — the per-rank
//! received volume stays flat as the ring grows. Rank 0 keeps its
//! receive-before-send ordering in both phases, so the deadlock-freedom
//! argument above carries over unchanged.
//!
//! Under `--sparse-shards` the same 2(n-1)-hop schedule runs with
//! [`Frame::SparseShard`] hops instead: each hop carries only a
//! shard's live `(index, value)` entries (indices re-based to
//! shard-local on the wire, back to global on receive), so a hop costs
//! `entries · 8 B` instead of `shard_len · 4 B`. The injector re-top-ks
//! its own slice *before* the step-0 send when `shard_k > 0`, every
//! rank re-top-ks the merged partial before forwarding, and each cap's
//! discards stay on the capping rank as its residual (canonicalized at
//! complete) — exactly the [`reduce_sparse_shard_with`] schedule, so
//! the reduced entries and residuals are bit-identical to every other
//! transport ([`CostModel::rsag_sparse_link_bytes_ring`] predicts the
//! uncapped per-link volume).
//!
//! [`reduce_sparse_shard_with`]: crate::collectives::reduce_sparse_shard_with
//! [`CostModel::rsag_sparse_link_bytes_ring`]: crate::collectives::CostModel::rsag_sparse_link_bytes_ring
//! [`TcpTransport`]: crate::cluster::net::tcp::TcpTransport
//! [`CostModel::allgather_star`]: crate::collectives::CostModel::allgather_star
//! [`CostModel::rsag_link_bytes_ring`]: crate::collectives::CostModel::rsag_link_bytes_ring
//! [NetCfg]: crate::cluster::net::handshake::NetCfg

use crate::cluster::net::codec::{
    encode_frame, encode_frame_append, encode_shard_append, encode_sparse_shard_append,
    read_frame, read_frame_counted, write_bytes, write_frame, Frame,
};
use crate::cluster::net::handshake::{bind_with_retry, NetCfg};
use crate::cluster::transport::{FloatBufPool, Message, RoundToken, SparseRound, Transport};
use crate::cluster::CollectiveKind;
use crate::collectives::allreduce::shard_bounds;
use crate::collectives::sparse::{
    canonicalize_residual, merge_add_sparse, reduce_sparse_contributions_with, retain_top_k,
    SparseReduceScratch, SparseVec,
};
use crate::collectives::CostModel;
use crate::error::{Error, Result};
use crate::obs::{FlightRecorder, ObsCounters, RecKind};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};

/// Sentinel for [`RingTransport::poisoned_by`]: nobody attributed yet.
const NO_ATTRIBUTION: u64 = u64::MAX;

/// The two ring links of one rank (absent in a single-rank world).
struct Links {
    /// Dialed stream to rank `(rank + 1) % n` — the send side.
    right: TcpStream,
    /// Accepted stream from rank `(rank + n - 1) % n` — the receive side.
    left: TcpStream,
}

struct RingState {
    links: Option<Links>,
    generation: u64,
    /// Rank-indexed slot board, retained across rounds (slots are
    /// `take()`n into the published slab each round).
    slots: Vec<Option<Message>>,
    /// Last round's published slab, kept for recycling: by the next
    /// round the caller has dropped its clone, so the slab is uniquely
    /// owned again and can be refilled in place.
    last: Option<Arc<[Message]>>,
    /// Persistent encode buffer for outgoing hop frames.
    enc_buf: Vec<u8>,
    /// Persistent decode scratch for incoming hop frames.
    dec_buf: Vec<u8>,
    /// `true` between a split-phase begin and its complete/abandon —
    /// rejects double-starts (one outstanding round per rank).
    pending: bool,
    /// Sparse-rsag injector-slice staging buffer (begin and rank 0's
    /// deferred step-0 send build the capped slice here).
    sv_send: SparseVec,
    /// Entries the begin-time injector cap discarded, carried until
    /// complete hands over the caller's residual buffer (one
    /// outstanding round per rank, so one stash suffices).
    residual_stash: SparseVec,
    /// [`retain_top_k`] permutation scratch, reused across hops.
    perm: Vec<u32>,
    /// Global → shard-local index staging for outgoing sparse hops.
    rebase: Vec<u32>,
    /// Sparse-rsag phase-2 staging: reduced entry lists per chunk, so
    /// the output can be assembled in position order. Grown lazily to
    /// `n`, cleared every round.
    shard_parts: Vec<SparseVec>,
}

/// Ring transport for one process-local rank of an n-rank cluster.
pub struct RingTransport {
    n: usize,
    rank: usize,
    state: Mutex<RingState>,
    /// Membership epoch this ring was formed at: 0 for the initial
    /// rendezvous, bumped instances are assembled by the elastic layer
    /// after a re-formation.
    epoch: u64,
    /// `try_clone`d link handles used only by [`Transport::abort`],
    /// which must not take the state lock (a blocked round holds it).
    shutdown_handles: Vec<TcpStream>,
    poisoned: AtomicBool,
    /// Rank attributed with the poisoning ([`NO_ATTRIBUTION`] until
    /// poisoned; first attribution wins and rides the forwarded notice).
    poisoned_by: AtomicU64,
    /// Mirror of the state generation, updated at begin/complete, so
    /// [`Transport::abort`] can stamp its notice without taking the
    /// state lock (a blocked — or panicking — round may hold it).
    gen_mirror: AtomicU64,
    /// Wire/payload/round counters for this process's rank, bumped at
    /// the exact hop read/write sites so gross bytes match the links.
    obs: ObsCounters,
    /// `--obs-flight` recorder; empty (and costless) unless attached.
    flight: OnceLock<Arc<FlightRecorder>>,
}

/// Host part of a `host:port` address (IPv6 `[..]:port` supported).
pub(crate) fn host_of(addr: &str) -> &str {
    match addr.rsplit_once(':') {
        Some((h, _)) => h,
        None => addr,
    }
}

/// A wildcard bind host (rank 0 started with `--coord-addr
/// 0.0.0.0:…`) cannot be *dialed* — substitute the host this client
/// actually reached the coordinator through. Only the coordinator's
/// own ring address can be wildcard (client addresses are built from
/// observed peer IPs), and only rank `n - 1` receives it.
pub(crate) fn substitute_wildcard_host(addr: String, fallback_host: &str) -> String {
    match host_of(&addr) {
        "0.0.0.0" | "[::]" => match addr.rsplit_once(':') {
            Some((_, port)) => format!("{fallback_host}:{port}"),
            None => addr,
        },
        _ => addr,
    }
}

/// Bind-all ring-listener address in the coordinator's address family
/// (a bracketed-IPv6 coordinator host means the advertised neighbor
/// addresses will be IPv6, so the listener must be too).
pub(crate) fn wildcard_listen_addr(coord_host: &str) -> &'static str {
    if coord_host.starts_with('[') {
        "[::]:0"
    } else {
        "0.0.0.0:0"
    }
}

fn set_round_timeouts(stream: &TcpStream, cfg: &NetCfg) -> Result<()> {
    stream.set_read_timeout(Some(cfg.io_timeout))?;
    stream.set_write_timeout(Some(cfg.io_timeout))?;
    stream.set_nodelay(true)?;
    Ok(())
}

/// Dial `addr` (retrying until `deadline` — the neighbor's listener is
/// bound before its Hello, but its process may be slower to schedule)
/// and identify as `my_rank` with a [`Frame::RingLink`].
pub(crate) fn dial_right(
    addr: &str,
    my_rank: usize,
    deadline: Instant,
    cfg: &NetCfg,
) -> Result<TcpStream> {
    let mut stream = loop {
        match TcpStream::connect(addr) {
            Ok(s) => break s,
            Err(e) => {
                if Instant::now() >= deadline {
                    return Err(Error::net(format!(
                        "rank {my_rank} cannot reach right neighbor at {addr}: {e}"
                    )));
                }
                std::thread::sleep(Duration::from_millis(10));
            }
        }
    };
    set_round_timeouts(&stream, cfg)?;
    write_frame(
        &mut stream,
        &Frame::RingLink {
            rank: my_rank as u32,
        },
    )?;
    Ok(stream)
}

/// Accept the left neighbor on this rank's ring listener, validating its
/// [`Frame::RingLink`] claim; stray connections (port scanners, a
/// mis-dialed rank) are rejected and the wait continues to `deadline`.
pub(crate) fn accept_left(
    listener: &TcpListener,
    expect_rank: usize,
    deadline: Instant,
    cfg: &NetCfg,
) -> Result<TcpStream> {
    listener.set_nonblocking(true)?;
    loop {
        let remaining = deadline.saturating_duration_since(Instant::now());
        if remaining.is_zero() {
            return Err(Error::net(format!(
                "ring link rendezvous timed out: left neighbor (rank {expect_rank}) \
                 never dialed in within {:?}",
                cfg.connect_timeout
            )));
        }
        match listener.accept() {
            Ok((stream, _)) => {
                stream.set_nonblocking(false)?;
                // the RingLink read must not eat the whole budget
                stream.set_read_timeout(Some(
                    remaining.min(cfg.io_timeout).max(Duration::from_millis(10)),
                ))?;
                stream.set_write_timeout(Some(cfg.io_timeout))?;
                let mut stream = stream;
                match read_frame(&mut stream) {
                    Ok(Frame::RingLink { rank }) if rank as usize == expect_rank => {
                        set_round_timeouts(&stream, cfg)?;
                        return Ok(stream);
                    }
                    Ok(Frame::RingLink { rank }) => {
                        let _ = write_frame(
                            &mut stream,
                            &Frame::Reject {
                                reason: format!(
                                    "this listener expects rank {expect_rank}, not rank {rank}"
                                ),
                            },
                        );
                    }
                    Ok(other) => {
                        let _ = write_frame(
                            &mut stream,
                            &Frame::Reject {
                                reason: format!("expected RingLink, got {other:?}"),
                            },
                        );
                    }
                    Err(_) => {
                        // undecodable garbage: drop it, keep waiting
                    }
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) => return Err(Error::net(format!("ring accept failed: {e}"))),
        }
    }
}

/// Coordinator side of the ring bootstrap: collect one valid
/// [`Frame::HelloRing`] per rank in `1..n` on the coordinator address,
/// answer each with its right neighbor's ring address, and return once
/// every bootstrap stream is released. `my_ring_addr` is rank 0's own
/// ring listener (rank `n - 1`'s right neighbor).
fn coordinate_ring(n: usize, cfg: &NetCfg, my_ring_addr: &str) -> Result<Vec<String>> {
    // retry-with-backoff closes the free-port TOCTOU race under
    // `launch`, exactly as on the star hub (see `bind_with_retry`)
    let deadline = Instant::now() + cfg.connect_timeout;
    let listener = bind_with_retry(&cfg.coord_addr, deadline)?;
    coordinate_ring_on(&listener, n, cfg, my_ring_addr)
}

/// [`coordinate_ring`] over an already-bound coordinator listener. The
/// elastic coordinator retains its listener across membership epochs
/// (survivors and late joiners re-rendezvous on the same address), so
/// the bootstrap accept loop must be callable without re-binding.
pub(crate) fn coordinate_ring_on(
    listener: &TcpListener,
    n: usize,
    cfg: &NetCfg,
    my_ring_addr: &str,
) -> Result<Vec<String>> {
    listener.set_nonblocking(true)?;
    let deadline = Instant::now() + cfg.connect_timeout;
    let mut peers: Vec<Option<(TcpStream, String)>> = (0..n).map(|_| None).collect();
    let mut missing = n - 1;
    while missing > 0 {
        let remaining = deadline.saturating_duration_since(Instant::now());
        if remaining.is_zero() {
            let absent: Vec<String> = peers
                .iter()
                .enumerate()
                .skip(1)
                .filter(|(_, s)| s.is_none())
                .map(|(r, _)| r.to_string())
                .collect();
            return Err(Error::net(format!(
                "ring rendezvous timed out after {:?}: still waiting for rank(s) {}",
                cfg.connect_timeout,
                absent.join(", ")
            )));
        }
        match listener.accept() {
            Ok((stream, _)) => {
                stream.set_nonblocking(false)?;
                stream.set_read_timeout(Some(
                    remaining.min(cfg.io_timeout).max(Duration::from_millis(10)),
                ))?;
                stream.set_write_timeout(Some(cfg.io_timeout))?;
                let mut stream = stream;
                match read_frame(&mut stream) {
                    Ok(Frame::HelloRing { world, rank, port }) => {
                        let reject = if world as usize != n {
                            Some(format!(
                                "world size mismatch: claim {world}, coordinator runs {n}"
                            ))
                        } else if rank == 0 || rank as usize >= n {
                            Some(format!("rank {rank} out of range 1..{n}"))
                        } else if peers[rank as usize].is_some() {
                            Some(format!("rank {rank} already claimed"))
                        } else {
                            None
                        };
                        match reject {
                            Some(reason) => {
                                let _ = write_frame(&mut stream, &Frame::Reject { reason });
                            }
                            None => {
                                let ip = stream.peer_addr()?.ip();
                                let ring_addr = SocketAddr::new(ip, port).to_string();
                                peers[rank as usize] = Some((stream, ring_addr));
                                missing -= 1;
                            }
                        }
                    }
                    Ok(Frame::Hello { .. }) => {
                        let _ = write_frame(
                            &mut stream,
                            &Frame::Reject {
                                reason: "this coordinator runs the ring transport; \
                                         expected HelloRing (transport mismatch?)"
                                    .to_string(),
                            },
                        );
                    }
                    Ok(other) => {
                        let _ = write_frame(
                            &mut stream,
                            &Frame::Reject {
                                reason: format!("expected HelloRing, got {other:?}"),
                            },
                        );
                    }
                    Err(_) => {
                        // undecodable (wrong version / garbage): drop it
                    }
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) => return Err(Error::net(format!("ring coordinator accept failed: {e}"))),
        }
    }
    // every slot claimed: build the rank-indexed ring address table and
    // release each rank with its right neighbor's address
    let mut addrs: Vec<String> = vec![my_ring_addr.to_string()];
    for slot in peers.iter().skip(1) {
        let (_, addr) = slot.as_ref().expect("all slots claimed above");
        addrs.push(addr.clone());
    }
    for (rank, slot) in peers.iter_mut().enumerate().skip(1) {
        let (stream, _) = slot.as_mut().expect("all slots claimed above");
        write_frame(
            stream,
            &Frame::WelcomeRing {
                world: n as u32,
                right_addr: addrs[(rank + 1) % n].clone(),
            },
        )?;
    }
    // bootstrap streams drop here; the data path is the ring links only
    Ok(addrs)
}

impl RingTransport {
    /// Rank 0: bind the ring listener and the coordinator address, seat
    /// ranks `1..n`, then join the ring itself.
    pub fn hub(n: usize, cfg: &NetCfg) -> Result<Self> {
        if n == 0 {
            return Err(Error::invalid("world size must be >= 1"));
        }
        if n == 1 {
            return Ok(Self::linkless(1, 0, 0));
        }
        let host = host_of(&cfg.coord_addr);
        let ring_listener = TcpListener::bind(format!("{host}:0")).map_err(|e| {
            Error::net(format!("rank 0 cannot bind its ring listener on {host}: {e}"))
        })?;
        let my_ring_addr = ring_listener.local_addr()?.to_string();
        let addrs = coordinate_ring(n, cfg, &my_ring_addr)?;
        // link establishment gets its own fresh budget: the rendezvous
        // above may legitimately have consumed most of connect_timeout
        // waiting for a slow rank, and that rank still needs time to
        // process its WelcomeRing and dial in
        let deadline = Instant::now() + cfg.connect_timeout;
        // dial right first (the neighbor's listener is already bound, so
        // the connect lands in its backlog), then accept left
        let right = dial_right(&addrs[1], 0, deadline, cfg)?;
        let left = accept_left(&ring_listener, n - 1, deadline, cfg)?;
        Self::assemble(n, 0, right, left, 0)
    }

    /// Ranks 1..n: bind a ring listener, claim `rank` at the
    /// coordinator, then dial the right neighbor and accept the left.
    pub fn client(n: usize, rank: usize, cfg: &NetCfg) -> Result<Self> {
        if rank == 0 || rank >= n {
            return Err(Error::invalid(format!(
                "client rank {rank} out of range 1..{n} (rank 0 is the coordinator)"
            )));
        }
        let ring_listener = TcpListener::bind(wildcard_listen_addr(host_of(&cfg.coord_addr)))
            .map_err(|e| Error::net(format!("rank {rank} cannot bind its ring listener: {e}")))?;
        let ring_port = ring_listener.local_addr()?.port();
        let deadline = Instant::now() + cfg.connect_timeout;
        // --- bootstrap: claim the rank, learn the right neighbor
        let mut coord = loop {
            match TcpStream::connect(&cfg.coord_addr) {
                Ok(s) => break s,
                Err(e) => {
                    if Instant::now() >= deadline {
                        return Err(Error::net(format!(
                            "cannot reach ring coordinator at {} within {:?}: {e}",
                            cfg.coord_addr, cfg.connect_timeout
                        )));
                    }
                    std::thread::sleep(Duration::from_millis(25));
                }
            }
        };
        // WelcomeRing may take the whole rendezvous budget (the
        // coordinator waits for every rank before releasing anyone)
        coord.set_read_timeout(Some(cfg.connect_timeout))?;
        coord.set_write_timeout(Some(cfg.io_timeout))?;
        write_frame(
            &mut coord,
            &Frame::HelloRing {
                world: n as u32,
                rank: rank as u32,
                port: ring_port,
            },
        )?;
        let right_addr = match read_frame(&mut coord)? {
            Frame::WelcomeRing { world, right_addr } if world as usize == n => right_addr,
            Frame::WelcomeRing { world, .. } => {
                return Err(Error::protocol(format!(
                    "coordinator confirmed world {world}, expected {n}"
                )))
            }
            Frame::Reject { reason } => {
                return Err(Error::protocol(format!(
                    "coordinator rejected rank {rank}: {reason}"
                )))
            }
            other => {
                return Err(Error::protocol(format!(
                    "expected WelcomeRing, got {other:?}"
                )))
            }
        };
        drop(coord);
        // the coordinator's own ring address may carry a wildcard bind
        // host; dial the host this client reached the coordinator on
        let right_addr = substitute_wildcard_host(right_addr, host_of(&cfg.coord_addr));
        // --- data path: dial right, accept left, each on a fresh
        // budget (the WelcomeRing wait alone may legitimately have
        // consumed the whole rendezvous budget)
        let deadline = Instant::now() + cfg.connect_timeout;
        let right = dial_right(&right_addr, rank, deadline, cfg)?;
        let left = accept_left(&ring_listener, rank - 1, deadline, cfg)?;
        Self::assemble(n, rank, right, left, 0)
    }

    /// A single-rank ring needs no links; the elastic layer also uses
    /// this when a re-formation leaves one survivor.
    pub(crate) fn linkless(n: usize, rank: usize, epoch: u64) -> Self {
        RingTransport {
            n,
            rank,
            state: Mutex::new(RingState {
                links: None,
                generation: 0,
                slots: (0..n).map(|_| None).collect(),
                last: None,
                enc_buf: Vec::new(),
                dec_buf: Vec::new(),
                pending: false,
                sv_send: SparseVec::new(),
                residual_stash: SparseVec::new(),
                perm: Vec::new(),
                rebase: Vec::new(),
                shard_parts: Vec::new(),
            }),
            epoch,
            shutdown_handles: Vec::new(),
            poisoned: AtomicBool::new(false),
            poisoned_by: AtomicU64::new(NO_ATTRIBUTION),
            gen_mirror: AtomicU64::new(0),
            obs: ObsCounters::new(),
            flight: OnceLock::new(),
        }
    }

    /// Wire two established links into a transport. The elastic layer
    /// re-enters here after an epoch re-formation, with links dialed
    /// from `WelcomeEpoch`-advertised addresses.
    pub(crate) fn assemble(
        n: usize,
        rank: usize,
        right: TcpStream,
        left: TcpStream,
        epoch: u64,
    ) -> Result<Self> {
        let shutdown_handles = vec![right.try_clone()?, left.try_clone()?];
        Ok(RingTransport {
            n,
            rank,
            state: Mutex::new(RingState {
                links: Some(Links { right, left }),
                generation: 0,
                slots: (0..n).map(|_| None).collect(),
                last: None,
                enc_buf: Vec::new(),
                dec_buf: Vec::new(),
                pending: false,
                sv_send: SparseVec::new(),
                residual_stash: SparseVec::new(),
                perm: Vec::new(),
                rebase: Vec::new(),
                shard_parts: Vec::new(),
            }),
            epoch,
            shutdown_handles,
            poisoned: AtomicBool::new(false),
            poisoned_by: AtomicU64::new(NO_ATTRIBUTION),
            gen_mirror: AtomicU64::new(0),
            obs: ObsCounters::new(),
            flight: OnceLock::new(),
        })
    }

    /// The typed fault a poisoned ring surfaces: attributed to the rank
    /// that died when known, anonymous otherwise.
    fn poison_fault(&self) -> Error {
        let generation = self.gen_mirror.load(Ordering::SeqCst);
        match self.poisoned_by.load(Ordering::SeqCst) {
            NO_ATTRIBUTION => Error::poisoned(generation),
            r => Error::peer_lost(r as usize, generation),
        }
    }

    /// Poison the ring, attributing the failure to `by` (first
    /// attribution wins): best-effort [`Frame::Abort`] notice to both
    /// neighbors — stamped with the attributed rank and the mirrored
    /// generation, so the poison's origin survives the trip around the
    /// ring — then socket shutdown so blocked neighbors error out
    /// immediately. Every call lands a flight event; the counter bump
    /// and recorder dump fire on the first poisoning only.
    fn poison(&self, by: usize) {
        let already = self.poisoned.swap(true, Ordering::SeqCst);
        let _ = self.poisoned_by.compare_exchange(
            NO_ATTRIBUTION,
            by as u64,
            Ordering::SeqCst,
            Ordering::SeqCst,
        );
        let attributed = self.poisoned_by.load(Ordering::SeqCst);
        let generation = self.gen_mirror.load(Ordering::SeqCst);
        let abort_bytes = encode_frame(&Frame::Abort {
            rank: attributed as u32,
            generation,
        });
        for h in &self.shutdown_handles {
            // best-effort polite notice, then force any blocked neighbor
            // read to return; both may fail on an already-dead socket
            let mut w: &TcpStream = h;
            let _ = write_bytes(&mut w, &abort_bytes);
            let _ = h.shutdown(Shutdown::Both);
        }
        if let Some(fr) = self.flight.get() {
            fr.record(RecKind::Abort, generation, attributed, 0);
            if !already {
                fr.dump_to_log("abort poisoning");
            }
        }
        if !already {
            self.obs.abort();
        }
    }

    /// The rank this transport speaks for.
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// Read one hop frame from the left link with full obs accounting:
    /// gross wire bytes at the stream boundary, model-unit payload
    /// bytes, frame count, and — when a recorder is attached — a flight
    /// event. Deadline expiries are counted apart from peer loss, and
    /// either failure dumps the recorder for the postmortem.
    fn read_counted(
        &self,
        left: &mut TcpStream,
        dec_buf: &mut Vec<u8>,
        my_gen: u64,
        step: usize,
    ) -> Result<Frame> {
        match read_frame_counted(left, dec_buf) {
            Ok((frame, gross)) => {
                self.obs.wire_rx(gross);
                self.obs.frame_decoded();
                self.obs.payload_rx(frame.payload_bytes());
                if let Some(fr) = self.flight.get() {
                    fr.record(RecKind::FrameRx, my_gen, gross as u64, 0);
                }
                Ok(frame)
            }
            Err(e) => {
                if e.is_timeout() {
                    self.obs.deadline_wait();
                    if let Some(fr) = self.flight.get() {
                        fr.record(RecKind::Deadline, my_gen, 0, 0);
                        fr.dump_to_log("deadline expiry");
                    }
                } else if let Some(fr) = self.flight.get() {
                    fr.dump_to_log("mid-round peer loss");
                }
                Err(Error::net(format!(
                    "ring step {step}: reading from left neighbor: {e}"
                )))
            }
        }
    }

    /// Write pre-encoded hop bytes to the right link with full obs
    /// accounting; `payload` is the model-unit byte count carried.
    fn write_counted(
        &self,
        right: &mut TcpStream,
        bytes: &[u8],
        payload: usize,
        my_gen: u64,
        step: usize,
    ) -> Result<()> {
        write_bytes(right, bytes)
            .map_err(|e| Error::net(format!("ring step {step}: sending to right neighbor: {e}")))?;
        self.obs.wire_tx(bytes.len());
        self.obs.payload_tx(payload);
        if let Some(fr) = self.flight.get() {
            fr.record(RecKind::FrameTx, my_gen, bytes.len() as u64, payload as u64);
        }
        Ok(())
    }

    /// One forwarding hop out: encode board slot `send_idx` (an `Arc`
    /// refcount bump, not a payload copy) into the persistent buffer and
    /// push it to the right neighbor.
    fn send_step(
        &self,
        links: &mut Links,
        enc_buf: &mut Vec<u8>,
        slots: &[Option<Message>],
        send_idx: usize,
        my_gen: u64,
        step: usize,
    ) -> Result<()> {
        enc_buf.clear();
        let fwd = slots[send_idx]
            .as_ref()
            .expect("forwarding order fills the slot before it is sent")
            .clone();
        let payload = fwd.payload_bytes();
        encode_frame_append(
            &Frame::Data {
                generation: my_gen,
                msg: fwd,
            },
            enc_buf,
        );
        self.obs.frame_encoded();
        self.write_counted(&mut links.right, enc_buf, payload, my_gen, step)
    }

    /// One forwarding hop in: read a generation-stamped frame from the
    /// left neighbor into board slot `recv_idx`.
    fn recv_step(
        &self,
        links: &mut Links,
        dec_buf: &mut Vec<u8>,
        slots: &mut [Option<Message>],
        recv_idx: usize,
        my_gen: u64,
        step: usize,
    ) -> Result<()> {
        let frame = self.read_counted(&mut links.left, dec_buf, my_gen, step)?;
        slots[recv_idx] = Some(super::expect_data(frame, my_gen, "left neighbor")?);
        Ok(())
    }

    /// One reduce-scatter hop out: encode `vals` as a [`Frame::Shard`]
    /// straight from the slice (no intermediate `Vec`) into the
    /// persistent buffer and push it to the right neighbor.
    fn send_shard(
        &self,
        links: &mut Links,
        enc_buf: &mut Vec<u8>,
        my_gen: u64,
        step: usize,
        chunk: usize,
        vals: &[f32],
    ) -> Result<()> {
        enc_buf.clear();
        encode_shard_append(enc_buf, my_gen, step as u32, chunk as u32, vals);
        self.obs.frame_encoded();
        let payload = vals.len() * CostModel::DENSE_ENTRY_BYTES;
        self.write_counted(&mut links.right, enc_buf, payload, my_gen, step)
    }

    /// One reduce-scatter hop in: read a [`Frame::Shard`] from the left
    /// neighbor and validate its full schedule stamp (round, step, chunk
    /// id, length) — any divergence is a typed error, never a silent mix
    /// of chunks.
    #[allow(clippy::too_many_arguments)]
    fn recv_shard(
        &self,
        links: &mut Links,
        dec_buf: &mut Vec<u8>,
        my_gen: u64,
        step: usize,
        chunk: usize,
        want_len: usize,
    ) -> Result<Vec<f32>> {
        let frame = self.read_counted(&mut links.left, dec_buf, my_gen, step)?;
        match frame {
            Frame::Shard {
                generation,
                step: got_step,
                chunk: got_chunk,
                vals,
            } => {
                if generation != my_gen {
                    return Err(Error::protocol(format!(
                        "generation mismatch from left neighbor: got {generation}, \
                         expected {my_gen} — workers diverged"
                    )));
                }
                if got_step as usize != step || got_chunk as usize != chunk {
                    return Err(Error::protocol(format!(
                        "reduce-scatter schedule divergence: got chunk {got_chunk} at \
                         step {got_step}, expected chunk {chunk} at step {step}"
                    )));
                }
                if vals.len() != want_len {
                    return Err(Error::protocol(format!(
                        "chunk {chunk} carries {} values, expected {want_len} — \
                         contribution lengths diverged",
                        vals.len()
                    )));
                }
                Ok(vals)
            }
            Frame::Abort { rank, generation } => Err(super::abort_error(rank, generation)),
            Frame::Data { .. } => Err(Error::protocol(
                "expected a reduce-scatter shard from the left neighbor, got a \
                 board frame — workers diverged",
            )),
            other => Err(Error::protocol(format!(
                "expected a reduce-scatter shard, got {other:?}"
            ))),
        }
    }

    /// One sparse reduce-scatter hop out: re-base the entry list's
    /// global positions to shard-local (`bounds.0` is the shard start)
    /// in the persistent staging buffer, encode a
    /// [`Frame::SparseShard`] straight from the slices, and push it to
    /// the right neighbor. A hop charges `entries · 8 B` of payload.
    #[allow(clippy::too_many_arguments)]
    fn send_sparse_shard(
        &self,
        links: &mut Links,
        enc_buf: &mut Vec<u8>,
        rebase: &mut Vec<u32>,
        my_gen: u64,
        step: usize,
        chunk: usize,
        bounds: (usize, usize),
        sv: &SparseVec,
    ) -> Result<()> {
        let (cs, ce) = bounds;
        rebase.clear();
        rebase.extend(sv.idx.iter().map(|&i| i - cs as u32));
        enc_buf.clear();
        encode_sparse_shard_append(
            enc_buf,
            my_gen,
            step as u32,
            chunk as u32,
            (ce - cs) as u32,
            rebase,
            &sv.val,
        );
        self.obs.frame_encoded();
        if let Some(fr) = self.flight.get() {
            fr.record(RecKind::SparseShard, my_gen, sv.len() as u64, 0);
        }
        self.write_counted(&mut links.right, enc_buf, sv.payload_bytes(), my_gen, step)
    }

    /// One sparse reduce-scatter hop in: read a [`Frame::SparseShard`]
    /// from the left neighbor, validate its full schedule stamp (round,
    /// step, chunk id, shard length) and re-base the shard-local
    /// positions back to global. The codec already rejected unsorted or
    /// out-of-shard-bounds indices as typed errors at decode.
    fn recv_sparse_shard(
        &self,
        links: &mut Links,
        dec_buf: &mut Vec<u8>,
        my_gen: u64,
        step: usize,
        chunk: usize,
        bounds: (usize, usize),
    ) -> Result<SparseVec> {
        let frame = self.read_counted(&mut links.left, dec_buf, my_gen, step)?;
        match frame {
            Frame::SparseShard {
                generation,
                step: got_step,
                chunk: got_chunk,
                shard_len,
                mut idx,
                vals,
            } => {
                if generation != my_gen {
                    return Err(Error::protocol(format!(
                        "generation mismatch from left neighbor: got {generation}, \
                         expected {my_gen} — workers diverged"
                    )));
                }
                if got_step as usize != step || got_chunk as usize != chunk {
                    return Err(Error::protocol(format!(
                        "sparse reduce-scatter schedule divergence: got chunk \
                         {got_chunk} at step {got_step}, expected chunk {chunk} at \
                         step {step}"
                    )));
                }
                let (cs, ce) = bounds;
                if shard_len as usize != ce - cs {
                    return Err(Error::protocol(format!(
                        "sparse chunk {chunk} claims shard length {shard_len}, \
                         expected {} — union lengths diverged",
                        ce - cs
                    )));
                }
                for i in idx.iter_mut() {
                    *i += cs as u32;
                }
                if let Some(fr) = self.flight.get() {
                    fr.record(RecKind::SparseShard, my_gen, idx.len() as u64, 1);
                }
                Ok(SparseVec { idx, val: vals })
            }
            Frame::Abort { rank, generation } => Err(super::abort_error(rank, generation)),
            Frame::Shard { .. } => Err(Error::protocol(
                "expected a sparse shard from the left neighbor, got a dense one — \
                 workers disagree about --sparse-shards",
            )),
            Frame::Data { .. } => Err(Error::protocol(
                "expected a sparse reduce-scatter shard from the left neighbor, got \
                 a board frame — workers diverged",
            )),
            other => Err(Error::protocol(format!(
                "expected a sparse reduce-scatter shard, got {other:?}"
            ))),
        }
    }
}

impl Transport for RingTransport {
    fn n_ranks(&self) -> usize {
        self.n
    }

    fn allgather(&self, rank: usize, msg: Message) -> Result<Arc<[Message]>> {
        // the blocking round is the split phases back to back
        let token = self.allgather_begin(rank, msg)?;
        self.allgather_complete(rank, token)
    }

    fn allgather_begin(&self, rank: usize, msg: Message) -> Result<RoundToken> {
        if rank != self.rank {
            return Err(Error::invalid(format!(
                "this process's transport speaks for rank {}, not rank {rank}",
                self.rank
            )));
        }
        if self.poisoned.load(Ordering::SeqCst) {
            return Err(self.poison_fault());
        }
        let mut guard = self.state.lock().unwrap();
        let RingState {
            links,
            generation,
            slots,
            enc_buf,
            pending,
            ..
        } = &mut *guard;
        if *pending {
            return Err(Error::invariant(format!(
                "rank {} double-started a split-phase ring round (round {} is \
                 still in flight — finish or drop it first)",
                self.rank, *generation
            )));
        }
        let my_gen = *generation;
        self.gen_mirror.store(my_gen, Ordering::SeqCst);
        slots[rank] = Some(msg);
        if let Some(links) = links.as_mut() {
            if rank != 0 {
                // every non-coordinator rank sends first within a step,
                // so its step-0 chunk can go on the wire eagerly — the
                // overlap window between begin and complete is genuine
                // transfer time. Rank 0 must keep its receive-before-
                // send ordering (see the module docs): if it also wrote
                // eagerly, a cluster fully parked in its overlap windows
                // could deadlock on full socket buffers with nobody
                // draining.
                self.send_step(links, enc_buf, slots, rank, my_gen, 0)?;
            }
        }
        *pending = true;
        self.obs.round(CollectiveKind::Allgather);
        if let Some(fr) = self.flight.get() {
            fr.record(RecKind::RoundBegin, my_gen, 0, 0);
        }
        Ok(RoundToken::deferred(my_gen))
    }

    fn allgather_complete(&self, rank: usize, token: RoundToken) -> Result<Arc<[Message]>> {
        if rank != self.rank {
            return Err(Error::invalid(format!(
                "this process's transport speaks for rank {}, not rank {rank}",
                self.rank
            )));
        }
        let mut guard = self.state.lock().unwrap();
        let RingState {
            links,
            generation,
            slots,
            last,
            enc_buf,
            dec_buf,
            pending,
        } = &mut *guard;
        if !*pending {
            return Err(Error::invariant(format!(
                "rank {} completing a ring round it never started",
                self.rank
            )));
        }
        // cleared up front: an erroring round poisons the transport (the
        // worker contract), so there is nothing left to hand back anyway
        *pending = false;
        let my_gen = *generation;
        self.gen_mirror.store(my_gen, Ordering::SeqCst);
        if token.generation() != my_gen {
            return Err(Error::invariant(format!(
                "rank {} completing round {}, but the ring is at round {my_gen}",
                self.rank,
                token.generation()
            )));
        }
        if self.poisoned.load(Ordering::SeqCst) {
            return Err(self.poison_fault());
        }
        let n = self.n;
        // any early `?` below leaves the generation unchanged; the failed
        // worker aborts the transport, so no later round can mix with it
        if let Some(links) = links.as_mut() {
            for step in 0..n - 1 {
                let send_idx = (rank + n - step) % n;
                let recv_idx = (send_idx + n - 1) % n;
                if rank == 0 {
                    // receive-before-send breaks the ring's write cycle
                    // for payloads larger than the socket buffers (see
                    // module docs); every other rank sends first
                    self.recv_step(links, dec_buf, slots, recv_idx, my_gen, step)?;
                    self.send_step(links, enc_buf, slots, send_idx, my_gen, step)?;
                } else {
                    if step > 0 {
                        // step 0's send already happened in begin
                        self.send_step(links, enc_buf, slots, send_idx, my_gen, step)?;
                    }
                    self.recv_step(links, dec_buf, slots, recv_idx, my_gen, step)?;
                }
            }
        }
        // publish: refill last round's slab in place when the caller has
        // dropped it, else allocate a fresh one
        let board = crate::cluster::transport::publish_recycled(slots, last);
        *generation = my_gen.wrapping_add(1);
        self.gen_mirror.store(my_gen.wrapping_add(1), Ordering::SeqCst);
        if let Some(fr) = self.flight.get() {
            fr.record(RecKind::RoundComplete, my_gen, 0, 0);
        }
        Ok(board)
    }

    fn allgather_abandon(&self, rank: usize, token: RoundToken) {
        // peers need this rank's n-1 forwarding hops to complete the
        // round: run it to completion and discard the board; a broken
        // ring is poisoned so nobody waits out a dead link
        if self.allgather_complete(rank, token).is_err() {
            self.abort();
        }
    }

    fn rsag_begin(&self, rank: usize, contribution: Arc<Vec<f32>>) -> Result<RoundToken> {
        if rank != self.rank {
            return Err(Error::invalid(format!(
                "this process's transport speaks for rank {}, not rank {rank}",
                self.rank
            )));
        }
        if self.poisoned.load(Ordering::SeqCst) {
            return Err(self.poison_fault());
        }
        let mut guard = self.state.lock().unwrap();
        let RingState {
            links,
            generation,
            enc_buf,
            pending,
            ..
        } = &mut *guard;
        if *pending {
            return Err(Error::invariant(format!(
                "rank {} double-started a split-phase ring round (round {} is \
                 still in flight — finish or drop it first)",
                self.rank, *generation
            )));
        }
        let my_gen = *generation;
        self.gen_mirror.store(my_gen, Ordering::SeqCst);
        if let Some(links) = links.as_mut() {
            if rank != 0 {
                // same eager step-0 rationale as allgather_begin: every
                // non-coordinator rank sends first within a step, so its
                // own slice of chunk (rank - 1) mod n goes on the wire
                // now; rank 0 stays the ring's designated drainer and
                // defers even this send to complete
                let chunk = (rank + self.n - 1) % self.n;
                let (cs, ce) = shard_bounds(contribution.len(), self.n, chunk);
                self.send_shard(links, enc_buf, my_gen, 0, chunk, &contribution[cs..ce])?;
            }
        }
        *pending = true;
        self.obs.round(CollectiveKind::Rsag);
        if let Some(fr) = self.flight.get() {
            fr.record(RecKind::RoundBegin, my_gen, 1, 0);
        }
        // the contribution rides the token: complete adds it in place to
        // every partial that passes through this rank
        Ok(RoundToken::deferred_with_stash(
            my_gen,
            Message::Floats(contribution),
        ))
    }

    fn rsag_complete(
        &self,
        rank: usize,
        mut token: RoundToken,
        shards: &mut FloatBufPool,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        // shard hops are decoded into fresh per-hop buffers (the socket
        // decode allocates regardless); the pool stays unused here
        let _ = shards;
        if rank != self.rank {
            return Err(Error::invalid(format!(
                "this process's transport speaks for rank {}, not rank {rank}",
                self.rank
            )));
        }
        let mut guard = self.state.lock().unwrap();
        let RingState {
            links,
            generation,
            enc_buf,
            dec_buf,
            pending,
            ..
        } = &mut *guard;
        if !*pending {
            return Err(Error::invariant(format!(
                "rank {} completing a ring round it never started",
                self.rank
            )));
        }
        *pending = false;
        let my_gen = *generation;
        self.gen_mirror.store(my_gen, Ordering::SeqCst);
        if token.generation() != my_gen {
            return Err(Error::invariant(format!(
                "rank {} completing round {}, but the ring is at round {my_gen}",
                self.rank,
                token.generation()
            )));
        }
        if self.poisoned.load(Ordering::SeqCst) {
            return Err(self.poison_fault());
        }
        let contribution = match token.take_stash() {
            Some(Message::Floats(v)) => v,
            _ => {
                return Err(Error::invariant(
                    "ring reduce token lost its stashed contribution",
                ))
            }
        };
        let n = self.n;
        let len = contribution.len();
        out.clear();
        out.resize(len, 0.0);
        let links = match links.as_mut() {
            Some(l) => l,
            None => {
                // single-rank world: the reduce is the identity
                out.copy_from_slice(&contribution);
                *generation = my_gen.wrapping_add(1);
        self.gen_mirror.store(my_gen.wrapping_add(1), Ordering::SeqCst);
                if let Some(fr) = self.flight.get() {
                    fr.record(RecKind::RoundComplete, my_gen, 1, 0);
                }
                return Ok(());
            }
        };
        // phase 1 — reduce-scatter: at step s forward the partial of
        // chunk (rank - 1 - s) mod n and receive chunk (rank - 2 - s)
        // mod n, adding the own contribution in place; after n - 1
        // steps `carry` is this rank's fully reduced shard, summed in
        // the canonical ring order (injector rank + 1 first, owner
        // last). Rank 0 receives before sending in every step — the
        // send uses the previous step's carry, which is already in
        // hand, so the drainer ordering costs nothing.
        let mut carry: Vec<f32> = Vec::new();
        for step in 0..n - 1 {
            let recv_chunk = (rank + 2 * n - 2 - step) % n;
            let (rs, re) = shard_bounds(len, n, recv_chunk);
            let send_chunk = (rank + 2 * n - 1 - step) % n;
            if rank == 0 {
                let mut vals =
                    self.recv_shard(links, dec_buf, my_gen, step, recv_chunk, re - rs)?;
                if step == 0 {
                    let (cs, ce) = shard_bounds(len, n, send_chunk);
                    self.send_shard(
                        links,
                        enc_buf,
                        my_gen,
                        step,
                        send_chunk,
                        &contribution[cs..ce],
                    )?;
                } else {
                    self.send_shard(links, enc_buf, my_gen, step, send_chunk, &carry)?;
                }
                for (v, &x) in vals.iter_mut().zip(contribution[rs..re].iter()) {
                    *v += x;
                }
                carry = vals;
            } else {
                if step > 0 {
                    // step 0's send already happened in begin
                    self.send_shard(links, enc_buf, my_gen, step, send_chunk, &carry)?;
                }
                let mut vals =
                    self.recv_shard(links, dec_buf, my_gen, step, recv_chunk, re - rs)?;
                for (v, &x) in vals.iter_mut().zip(contribution[rs..re].iter()) {
                    *v += x;
                }
                carry = vals;
            }
        }
        // phase 2 — all-gather of the n reduced shards: land the own
        // shard, then forward reduced chunks for n - 1 more hops,
        // copying each received shard into `out`
        let (os, oe) = shard_bounds(len, n, rank);
        out[os..oe].copy_from_slice(&carry);
        for t in 0..n - 1 {
            let step = n - 1 + t;
            let send_chunk = (rank + n - t) % n;
            let recv_chunk = (rank + 2 * n - 1 - t) % n;
            let (rs, re) = shard_bounds(len, n, recv_chunk);
            if rank == 0 {
                let vals = self.recv_shard(links, dec_buf, my_gen, step, recv_chunk, re - rs)?;
                self.send_shard(links, enc_buf, my_gen, step, send_chunk, &carry)?;
                out[rs..re].copy_from_slice(&vals);
                carry = vals;
            } else {
                self.send_shard(links, enc_buf, my_gen, step, send_chunk, &carry)?;
                let vals = self.recv_shard(links, dec_buf, my_gen, step, recv_chunk, re - rs)?;
                out[rs..re].copy_from_slice(&vals);
                carry = vals;
            }
        }
        *generation = my_gen.wrapping_add(1);
        self.gen_mirror.store(my_gen.wrapping_add(1), Ordering::SeqCst);
        if let Some(fr) = self.flight.get() {
            fr.record(RecKind::RoundComplete, my_gen, 1, 0);
        }
        Ok(())
    }

    fn rsag_abandon(&self, rank: usize, token: RoundToken) {
        // peers mid-reduce depend on this rank's 2(n-1) hops: run the
        // round to completion and discard the result; a broken ring is
        // poisoned so nobody waits out a dead link
        let mut shards = FloatBufPool::new();
        let mut out = Vec::new();
        if self
            .rsag_complete(rank, token, &mut shards, &mut out)
            .is_err()
        {
            self.abort();
        }
    }

    fn rsag_sparse_begin(
        &self,
        rank: usize,
        contribution: Arc<SparseVec>,
        round: SparseRound,
    ) -> Result<RoundToken> {
        if rank != self.rank {
            return Err(Error::invalid(format!(
                "this process's transport speaks for rank {}, not rank {rank}",
                self.rank
            )));
        }
        if self.poisoned.load(Ordering::SeqCst) {
            return Err(self.poison_fault());
        }
        let mut guard = self.state.lock().unwrap();
        let RingState {
            links,
            generation,
            enc_buf,
            pending,
            sv_send,
            residual_stash,
            perm,
            rebase,
            ..
        } = &mut *guard;
        if *pending {
            return Err(Error::invariant(format!(
                "rank {} double-started a split-phase ring round (round {} is \
                 still in flight — finish or drop it first)",
                self.rank, *generation
            )));
        }
        if let Some(&last) = contribution.idx.last() {
            if last as usize >= round.union_len {
                return Err(Error::invariant(format!(
                    "rank {rank}'s sparse contribution indexes position {last}, \
                     union length is {} — workers diverged",
                    round.union_len
                )));
            }
        }
        let my_gen = *generation;
        self.gen_mirror.store(my_gen, Ordering::SeqCst);
        if let Some(links) = links.as_mut() {
            if rank != 0 {
                // same eager step-0 rationale as rsag_begin, with the
                // sparse twist: the injector slice is re-top-k'd before
                // it ever hits the wire, and the cap's discards wait in
                // the stash until complete hands over the caller's
                // residual buffer. Rank 0 stays the designated drainer
                // and defers even this send to complete.
                let chunk = (rank + self.n - 1) % self.n;
                let (cs, ce) = shard_bounds(round.union_len, self.n, chunk);
                let (ci, cv) = contribution.range(cs, ce);
                sv_send.copy_from(ci, cv);
                if round.shard_k > 0 && sv_send.len() > round.shard_k {
                    retain_top_k(sv_send, round.shard_k, perm, |i, v| {
                        residual_stash.push_entry(i, v)
                    });
                }
                self.send_sparse_shard(
                    links,
                    enc_buf,
                    rebase,
                    my_gen,
                    0,
                    chunk,
                    (cs, ce),
                    sv_send,
                )?;
            }
        }
        *pending = true;
        self.obs.round(CollectiveKind::Rsag);
        if let Some(fr) = self.flight.get() {
            fr.record(RecKind::RoundBegin, my_gen, 2, 0);
        }
        // the contribution rides the token: complete merges it into
        // every partial that passes through this rank
        Ok(RoundToken::deferred_with_stash(
            my_gen,
            Message::Sparse(contribution),
        ))
    }

    fn rsag_sparse_complete(
        &self,
        rank: usize,
        mut token: RoundToken,
        round: SparseRound,
        scratch: &mut SparseReduceScratch,
        out: &mut SparseVec,
        residual: &mut SparseVec,
    ) -> Result<()> {
        if rank != self.rank {
            return Err(Error::invalid(format!(
                "this process's transport speaks for rank {}, not rank {rank}",
                self.rank
            )));
        }
        let mut guard = self.state.lock().unwrap();
        let RingState {
            links,
            generation,
            enc_buf,
            dec_buf,
            pending,
            sv_send,
            residual_stash,
            perm,
            rebase,
            shard_parts,
            ..
        } = &mut *guard;
        if !*pending {
            return Err(Error::invariant(format!(
                "rank {} completing a ring round it never started",
                self.rank
            )));
        }
        *pending = false;
        let my_gen = *generation;
        self.gen_mirror.store(my_gen, Ordering::SeqCst);
        if token.generation() != my_gen {
            return Err(Error::invariant(format!(
                "rank {} completing round {}, but the ring is at round {my_gen}",
                self.rank,
                token.generation()
            )));
        }
        if self.poisoned.load(Ordering::SeqCst) {
            return Err(self.poison_fault());
        }
        let contribution = match token.take_stash() {
            Some(Message::Sparse(v)) => v,
            _ => {
                return Err(Error::invariant(
                    "ring sparse reduce token lost its stashed contribution",
                ))
            }
        };
        let n = self.n;
        let len = round.union_len;
        // the begin-time injector-cap discards lead this rank's
        // residual; canonicalization at the end makes the collection
        // order immaterial
        residual.clear();
        for (&i, &v) in residual_stash.idx.iter().zip(residual_stash.val.iter()) {
            residual.push_entry(i, v);
        }
        residual_stash.clear();
        let links = match links.as_mut() {
            Some(l) => l,
            None => {
                // single-rank world: the canonical one-rank replay
                reduce_sparse_contributions_with(
                    1,
                    len,
                    |_| (&contribution.idx[..], &contribution.val[..]),
                    round.shard_k,
                    scratch,
                    out,
                    |_, i, v| residual.push_entry(i, v),
                );
                canonicalize_residual(residual, scratch);
                *generation = my_gen.wrapping_add(1);
        self.gen_mirror.store(my_gen.wrapping_add(1), Ordering::SeqCst);
                if let Some(fr) = self.flight.get() {
                    fr.record(RecKind::RoundComplete, my_gen, 2, 0);
                }
                return Ok(());
            }
        };
        // phase 1 — sparse reduce-scatter: same hop schedule as the
        // dense rsag, but each hop is the shard's live entry list; the
        // receiving rank merges its own slice into the partial
        // (partial first — the canonical [`reduce_sparse_shard_with`]
        // order) and re-top-ks the result before forwarding, keeping
        // the cap's discards as its own residual. Rank 0 receives
        // before sending in every step and defers its injector send to
        // step 0 here, capping it exactly as begin does for the others.
        let mut carry = SparseVec::new();
        for step in 0..n - 1 {
            let recv_chunk = (rank + 2 * n - 2 - step) % n;
            let (rs, re) = shard_bounds(len, n, recv_chunk);
            let send_chunk = (rank + 2 * n - 1 - step) % n;
            let (ss, se) = shard_bounds(len, n, send_chunk);
            if rank == 0 {
                let sv =
                    self.recv_sparse_shard(links, dec_buf, my_gen, step, recv_chunk, (rs, re))?;
                if step == 0 {
                    let (ci, cv) = contribution.range(ss, se);
                    sv_send.copy_from(ci, cv);
                    if round.shard_k > 0 && sv_send.len() > round.shard_k {
                        retain_top_k(sv_send, round.shard_k, perm, |i, v| {
                            residual.push_entry(i, v)
                        });
                    }
                    self.send_sparse_shard(
                        links,
                        enc_buf,
                        rebase,
                        my_gen,
                        step,
                        send_chunk,
                        (ss, se),
                        sv_send,
                    )?;
                } else {
                    self.send_sparse_shard(
                        links,
                        enc_buf,
                        rebase,
                        my_gen,
                        step,
                        send_chunk,
                        (ss, se),
                        &carry,
                    )?;
                }
                let (ci, cv) = contribution.range(rs, re);
                merge_add_sparse(&sv.idx, &sv.val, ci, cv, &mut scratch.merged);
                std::mem::swap(&mut carry, &mut scratch.merged);
            } else {
                if step > 0 {
                    // step 0's send already happened in begin
                    self.send_sparse_shard(
                        links,
                        enc_buf,
                        rebase,
                        my_gen,
                        step,
                        send_chunk,
                        (ss, se),
                        &carry,
                    )?;
                }
                let sv =
                    self.recv_sparse_shard(links, dec_buf, my_gen, step, recv_chunk, (rs, re))?;
                let (ci, cv) = contribution.range(rs, re);
                merge_add_sparse(&sv.idx, &sv.val, ci, cv, &mut scratch.merged);
                std::mem::swap(&mut carry, &mut scratch.merged);
            }
            if round.shard_k > 0 && carry.len() > round.shard_k {
                retain_top_k(&mut carry, round.shard_k, perm, |i, v| {
                    residual.push_entry(i, v)
                });
            }
        }
        // phase 2 — all-gather of the n reduced entry lists, staged
        // per chunk so `out` assembles in position order
        if shard_parts.len() < n {
            shard_parts.resize_with(n, SparseVec::new);
        }
        shard_parts[rank].copy_from(&carry.idx, &carry.val);
        for t in 0..n - 1 {
            let step = n - 1 + t;
            let send_chunk = (rank + n - t) % n;
            let (ss, se) = shard_bounds(len, n, send_chunk);
            let recv_chunk = (rank + 2 * n - 1 - t) % n;
            let (rs, re) = shard_bounds(len, n, recv_chunk);
            if rank == 0 {
                let sv =
                    self.recv_sparse_shard(links, dec_buf, my_gen, step, recv_chunk, (rs, re))?;
                self.send_sparse_shard(
                    links,
                    enc_buf,
                    rebase,
                    my_gen,
                    step,
                    send_chunk,
                    (ss, se),
                    &carry,
                )?;
                shard_parts[recv_chunk].copy_from(&sv.idx, &sv.val);
                carry = sv;
            } else {
                self.send_sparse_shard(
                    links,
                    enc_buf,
                    rebase,
                    my_gen,
                    step,
                    send_chunk,
                    (ss, se),
                    &carry,
                )?;
                let sv =
                    self.recv_sparse_shard(links, dec_buf, my_gen, step, recv_chunk, (rs, re))?;
                shard_parts[recv_chunk].copy_from(&sv.idx, &sv.val);
                carry = sv;
            }
        }
        out.clear();
        for part in shard_parts.iter_mut().take(n) {
            out.idx.extend_from_slice(&part.idx);
            out.val.extend_from_slice(&part.val);
            part.clear();
        }
        canonicalize_residual(residual, scratch);
        *generation = my_gen.wrapping_add(1);
        self.gen_mirror.store(my_gen.wrapping_add(1), Ordering::SeqCst);
        if let Some(fr) = self.flight.get() {
            fr.record(RecKind::RoundComplete, my_gen, 2, 0);
        }
        Ok(())
    }

    fn rsag_sparse_abandon(&self, rank: usize, token: RoundToken, round: SparseRound) {
        // peers mid-reduce depend on this rank's 2(n-1) hops: run the
        // round to completion into throwaway buffers; a broken ring is
        // poisoned so nobody waits out a dead link
        let mut scratch = SparseReduceScratch::new();
        let mut out = SparseVec::new();
        let mut residual = SparseVec::new();
        if self
            .rsag_sparse_complete(rank, token, round, &mut scratch, &mut out, &mut residual)
            .is_err()
        {
            self.abort();
        }
    }

    fn abort(&self) {
        // a local abort means THIS worker failed: neighbors learn which
        // rank died from the stamped notice
        self.poison(self.rank);
    }

    fn abort_from(&self, rank: usize) {
        self.poison(rank);
    }

    fn epoch(&self) -> u64 {
        self.epoch
    }

    fn counters(&self, rank: usize) -> Option<&ObsCounters> {
        (rank == self.rank).then_some(&self.obs)
    }

    fn attach_flight_recorder(&self, rank: usize, recorder: Arc<FlightRecorder>) {
        if rank == self.rank {
            let _ = self.flight.set(recorder);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::net::handshake::free_loopback_addr;
    use crate::cluster::transport::Endpoint;
    use crate::coordinator::SelectOutput;

    fn cfg(addr: &str) -> NetCfg {
        NetCfg {
            coord_addr: addr.to_string(),
            connect_timeout: Duration::from_secs(30),
            io_timeout: Duration::from_secs(10),
        }
    }

    /// Build an n-rank loopback ring: one joined transport per rank
    /// (coordinator at index 0), built concurrently.
    fn loopback_ring(n: usize) -> Vec<Arc<RingTransport>> {
        let addr = free_loopback_addr().unwrap();
        let mut client_handles = Vec::new();
        for rank in 1..n {
            let c = cfg(&addr);
            client_handles.push(std::thread::spawn(move || {
                RingTransport::client(n, rank, &c).map(Arc::new)
            }));
        }
        let hub = Arc::new(RingTransport::hub(n, &cfg(&addr)).unwrap());
        let mut out = vec![hub];
        for h in client_handles {
            out.push(h.join().unwrap().unwrap());
        }
        out
    }

    #[test]
    fn allgather_is_rank_indexed_over_rounds() {
        let n = 3;
        let rounds = 20;
        let tps = loopback_ring(n);
        let mut handles = Vec::new();
        for (rank, tp) in tps.into_iter().enumerate() {
            handles.push(std::thread::spawn(move || {
                let ep = Endpoint::new(rank, tp.as_ref());
                for round in 0..rounds {
                    let mine = (rank * 1000 + round) as f64;
                    let got = ep.allgather_f64(mine).unwrap();
                    let want: Vec<f64> = (0..n).map(|r| (r * 1000 + round) as f64).collect();
                    assert_eq!(got, want, "rank {rank} round {round}");
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn rsag_matches_the_canonical_shard_order_over_rounds() {
        use crate::collectives::allreduce::reduce_contributions_rsag_with;

        // order-probe data: ulp(1e8) = 8 for f32, so 1e8 + 1.0 == 1e8
        // and the summation order is observable in the result bits
        let probe = |rank: usize, round: usize, len: usize| -> Vec<f32> {
            (0..len)
                .map(|i| [1.0e8f32, 1.0, -1.0e8][(rank + i + round) % 3])
                .collect()
        };
        let n = 3;
        let len = 10;
        let rounds = 6;
        let tps = loopback_ring(n);
        let mut handles = Vec::new();
        for (rank, tp) in tps.into_iter().enumerate() {
            handles.push(std::thread::spawn(move || {
                let ep = Endpoint::new(rank, tp.as_ref());
                let mut shards = FloatBufPool::new();
                let mut out = Vec::new();
                for round in 0..rounds {
                    let mine = Arc::new(probe(rank, round, len));
                    if round % 2 == 0 {
                        tp.reduce_scatter_allgather(rank, mine, &mut shards, &mut out)
                            .unwrap();
                    } else {
                        // split-phase path lands the identical bits
                        let token = tp.rsag_begin(rank, mine).unwrap();
                        tp.rsag_complete(rank, token, &mut shards, &mut out)
                            .unwrap();
                    }
                    let mut want = Vec::new();
                    let parts: Vec<Vec<f32>> =
                        (0..n).map(|r| probe(r, round, len)).collect();
                    reduce_contributions_rsag_with(n, len, |r| &parts[r], &mut want);
                    let got: Vec<u32> = out.iter().map(|v| v.to_bits()).collect();
                    let want: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
                    assert_eq!(got, want, "rank {rank} round {round}");
                    // a board round between reduce rounds must still work
                    let board = ep.allgather_f64(rank as f64).unwrap();
                    assert_eq!(board, (0..n).map(|r| r as f64).collect::<Vec<_>>());
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn sparse_rsag_matches_the_lockstep_twin_bit_for_bit() {
        use crate::collectives::sparse::sparse_shard_allreduce_lockstep;

        // overlapping order-probe selections: ulp(1e8) = 8 for f32, so
        // the canonical merge order is observable in the reduced bits
        let probe = |rank: usize, round: usize, len: usize| -> SparseVec {
            let mut sv = SparseVec::new();
            for p in 0..len {
                if (p + rank) % 3 != 0 {
                    sv.push_entry(p as u32, [1.0e8f32, 1.0, -1.0e8][(rank + p + round) % 3]);
                }
            }
            sv
        };
        let n = 3;
        let len = 11;
        let rounds = 6;
        let tps = loopback_ring(n);
        let mut handles = Vec::new();
        for (rank, tp) in tps.into_iter().enumerate() {
            handles.push(std::thread::spawn(move || {
                let mut scratch = SparseReduceScratch::new();
                let mut out = SparseVec::new();
                let mut residual = SparseVec::new();
                for round in 0..rounds {
                    // alternate uncapped and per-hop re-top-k rounds,
                    // and blocking vs split-phase entry points
                    let shard_k = if round % 2 == 0 { 0 } else { 2 };
                    let sr = SparseRound {
                        union_len: len,
                        shard_k,
                    };
                    let mine = Arc::new(probe(rank, round, len));
                    if round % 2 == 0 {
                        tp.rsag_sparse(rank, mine, sr, &mut scratch, &mut out, &mut residual)
                            .unwrap();
                    } else {
                        let token = tp.rsag_sparse_begin(rank, mine, sr).unwrap();
                        tp.rsag_sparse_complete(
                            rank,
                            token,
                            sr,
                            &mut scratch,
                            &mut out,
                            &mut residual,
                        )
                        .unwrap();
                    }
                    let contribs: Vec<SparseVec> = (0..n).map(|r| probe(r, round, len)).collect();
                    let mut ls = SparseReduceScratch::new();
                    let mut entries = SparseVec::new();
                    let mut reduced = Vec::new();
                    let mut residuals: Vec<SparseVec> =
                        (0..n).map(|_| SparseVec::new()).collect();
                    let net = CostModel::paper_testbed(n);
                    let _ = sparse_shard_allreduce_lockstep(
                        &contribs,
                        len,
                        shard_k,
                        &net,
                        &mut ls,
                        &mut entries,
                        &mut reduced,
                        &mut residuals,
                    );
                    assert_eq!(out.idx, entries.idx, "rank {rank} round {round}");
                    let got: Vec<u32> = out.val.iter().map(|v| v.to_bits()).collect();
                    let want: Vec<u32> = entries.val.iter().map(|v| v.to_bits()).collect();
                    assert_eq!(got, want, "rank {rank} round {round}");
                    assert_eq!(
                        residual.idx, residuals[rank].idx,
                        "rank {rank} round {round} residual positions"
                    );
                    let got_r: Vec<u32> = residual.val.iter().map(|v| v.to_bits()).collect();
                    let want_r: Vec<u32> =
                        residuals[rank].val.iter().map(|v| v.to_bits()).collect();
                    assert_eq!(got_r, want_r, "rank {rank} round {round} residual values");
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn large_payloads_cannot_deadlock_the_reduce() {
        // each rank's contribution (512 KB) exceeds typical socket
        // buffers; rank 0's receive-first ordering must keep the 2(n-1)
        // hop reduce schedule making progress
        let n = 3;
        let len = 128 * 1024;
        let tps = loopback_ring(n);
        let mut handles = Vec::new();
        for (rank, tp) in tps.into_iter().enumerate() {
            handles.push(std::thread::spawn(move || {
                let mut shards = FloatBufPool::new();
                let mut out = Vec::new();
                for round in 0..2 {
                    let mine = Arc::new(vec![(rank + round) as f32; len]);
                    tp.reduce_scatter_allgather(rank, mine, &mut shards, &mut out)
                        .unwrap();
                    let want = (0..n).map(|r| (r + round) as f32).sum::<f32>();
                    assert_eq!(out.len(), len);
                    assert!(
                        out.iter().all(|&v| v == want),
                        "rank {rank} round {round}"
                    );
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn mixed_message_kinds_roundtrip_bit_exactly() {
        let n = 2;
        let tps = loopback_ring(n);
        let mut handles = Vec::new();
        for (rank, tp) in tps.into_iter().enumerate() {
            handles.push(std::thread::spawn(move || {
                let ep = Endpoint::new(rank, tp.as_ref());
                let sel = Arc::new(SelectOutput {
                    idx: vec![rank as u32, 100 + rank as u32],
                    val: vec![rank as f32, f32::NAN],
                });
                let sels = ep.allgather_select(sel).unwrap();
                assert_eq!(sels.len(), n);
                assert_eq!(sels[rank].idx[0], rank as u32);
                assert!(sels[0].val[1].is_nan() && sels[1].val[1].is_nan());
                let floats = ep.allgather_floats(Arc::new(vec![rank as f32; 4])).unwrap();
                assert_eq!(*floats[1], vec![1.0f32; 4]);
                let empty = ep
                    .allgather_select(Arc::new(SelectOutput::default()))
                    .unwrap();
                assert!(empty.iter().all(|s| s.is_empty()));
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn large_payloads_cannot_deadlock_the_ring() {
        // every rank's contribution (512 KB) exceeds typical socket
        // buffers; the rank-0 receive-first ordering must keep the ring
        // making progress
        let n = 3;
        let k = 128 * 1024;
        let tps = loopback_ring(n);
        let mut handles = Vec::new();
        for (rank, tp) in tps.into_iter().enumerate() {
            handles.push(std::thread::spawn(move || {
                let ep = Endpoint::new(rank, tp.as_ref());
                for round in 0..3 {
                    let mine = Arc::new(vec![(rank * 10 + round) as f32; k]);
                    let got = ep.allgather_floats(mine).unwrap();
                    for (r, v) in got.iter().enumerate() {
                        assert_eq!(v.len(), k);
                        assert_eq!(v[0], (r * 10 + round) as f32, "rank {rank} round {round}");
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn per_rank_counters_match_the_ring_link_model() {
        let n = 3;
        let len = 12; // divisible by n: shard chunks are equal-sized
        let tps = loopback_ring(n);
        let refs = tps.clone();
        let before: Vec<_> = refs
            .iter()
            .enumerate()
            .map(|(r, tp)| tp.counters(r).unwrap().snapshot())
            .collect();
        let mut handles = Vec::new();
        for (rank, tp) in tps.into_iter().enumerate() {
            handles.push(std::thread::spawn(move || {
                let ep = Endpoint::new(rank, tp.as_ref());
                let mut shards = FloatBufPool::new();
                let mut out = Vec::new();
                ep.allgather_floats(Arc::new(vec![rank as f32; len])).unwrap();
                ep.reduce_scatter_allgather(Arc::new(vec![1.0f32; len]), &mut shards, &mut out)
                    .unwrap();
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let net = CostModel::paper_testbed(n);
        let b = len * CostModel::DENSE_ENTRY_BYTES;
        // the ring is symmetric: every rank's link carries exactly
        // (n-1)·B per all-gather and 2(n-1)/n·B per rsag, each direction
        let want = (net.allgather_link_bytes_ring(b) + net.rsag_link_bytes_ring(b)) as u64;
        for (rank, tp) in refs.iter().enumerate() {
            let d = tp.counters(rank).unwrap().snapshot().since(&before[rank]);
            assert_eq!(d.payload_tx_bytes, want, "rank {rank} tx");
            assert_eq!(d.payload_rx_bytes, want, "rank {rank} rx");
            assert_eq!(d.rounds_allgather, 1, "rank {rank}");
            assert_eq!(d.rounds_rsag, 1, "rank {rank}");
            assert_eq!(d.aborts, 0, "rank {rank}");
            // gross wire bytes strictly exceed payload bytes (framing)
            assert!(d.wire_tx_bytes > d.payload_tx_bytes, "rank {rank}: {d:?}");
            assert!(d.wire_rx_bytes > d.payload_rx_bytes, "rank {rank}: {d:?}");
            // each instance speaks for exactly one rank
            assert!(tp.counters((rank + 1) % n).is_none());
        }
    }

    #[test]
    fn wrong_rank_call_is_rejected() {
        let tps = loopback_ring(2);
        let err = tps[1]
            .allgather(0, Message::Scalar(0.0))
            .unwrap_err()
            .to_string();
        assert!(err.contains("speaks for rank 1"), "{err}");
    }

    #[test]
    fn single_rank_world_needs_no_sockets() {
        let addr = free_loopback_addr().unwrap();
        let tp = RingTransport::hub(1, &cfg(&addr)).unwrap();
        let got = tp.allgather(0, Message::Scalar(4.5)).unwrap();
        assert_eq!(&got[..], &[Message::Scalar(4.5)]);
    }

    #[test]
    fn abort_breaks_the_ring_for_every_rank() {
        let n = 3;
        let tps = loopback_ring(n);
        // rank 2 dies; ranks 0 and 1 must error out of the round instead
        // of waiting forever on forwarded chunks that never arrive
        tps[2].abort();
        // surviving ranks follow the worker contract: abort on error so
        // the poison propagates around the ring instead of each rank
        // waiting out its own IO deadline
        let t0 = Arc::clone(&tps[0]);
        let h0 = std::thread::spawn(move || {
            let res = t0.allgather(0, Message::Scalar(0.0));
            if res.is_err() {
                t0.abort();
            }
            res.map(|_| ())
        });
        let t1 = Arc::clone(&tps[1]);
        let h1 = std::thread::spawn(move || {
            let res = t1.allgather(1, Message::Scalar(1.0));
            if res.is_err() {
                t1.abort();
            }
            res.map(|_| ())
        });
        assert!(h0.join().unwrap().is_err(), "rank 0 must surface the break");
        assert!(h1.join().unwrap().is_err(), "rank 1 must surface the break");
        // the aborting side fails fast locally
        let err = tps[2]
            .allgather(2, Message::Scalar(2.0))
            .unwrap_err()
            .to_string();
        assert!(err.contains("poisoned"), "{err}");
    }

    #[test]
    fn attributed_abort_surfaces_peer_lost() {
        let tps = loopback_ring(2);
        tps[0].abort_from(1);
        let err = tps[0].allgather(0, Message::Scalar(0.0)).unwrap_err();
        assert!(err.is_membership_fault(), "{err}");
        assert!(err.to_string().contains("peer rank 1 lost"), "{err}");
        // the first attribution wins: a later local abort does not
        // rewrite the postmortem
        tps[0].abort();
        let err = tps[0].allgather(0, Message::Scalar(0.0)).unwrap_err();
        assert!(err.to_string().contains("peer rank 1 lost"), "{err}");
    }

    #[test]
    fn epoch_stamp_rides_the_constructor() {
        let tp = RingTransport::linkless(1, 0, 4);
        assert_eq!(tp.epoch(), 4);
        let got = tp.allgather(0, Message::Scalar(1.5)).unwrap();
        assert_eq!(&got[..], &[Message::Scalar(1.5)]);
    }

    #[test]
    fn star_client_is_rejected_with_a_transport_hint() {
        let n = 2;
        let addr = free_loopback_addr().unwrap();
        let probe_addr = addr.clone();
        let probe = std::thread::spawn(move || {
            let deadline = Instant::now() + Duration::from_secs(10);
            let mut stream = loop {
                match TcpStream::connect(&probe_addr) {
                    Ok(s) => break s,
                    Err(e) => {
                        assert!(Instant::now() < deadline, "connect: {e}");
                        std::thread::sleep(Duration::from_millis(20));
                    }
                }
            };
            stream
                .set_read_timeout(Some(Duration::from_secs(10)))
                .unwrap();
            write_frame(&mut stream, &Frame::Hello { world: 2, rank: 1 }).unwrap();
            read_frame(&mut stream)
        });
        let hub_cfg = NetCfg {
            coord_addr: addr,
            connect_timeout: Duration::from_millis(1500),
            io_timeout: Duration::from_millis(500),
        };
        assert!(
            RingTransport::hub(n, &hub_cfg).is_err(),
            "a star Hello must not satisfy the ring rendezvous"
        );
        match probe.join().unwrap().unwrap() {
            Frame::Reject { reason } => {
                assert!(reason.contains("transport mismatch"), "{reason}")
            }
            other => panic!("expected Reject, got {other:?}"),
        }
    }

    #[test]
    fn host_of_handles_common_forms() {
        assert_eq!(host_of("127.0.0.1:29400"), "127.0.0.1");
        assert_eq!(host_of("localhost:0"), "localhost");
        assert_eq!(host_of("[::1]:29400"), "[::1]");
    }

    #[test]
    fn wildcard_coordinator_host_is_substituted_for_dialing() {
        // rank 0 bound 0.0.0.0; rank n-1 must dial the host it reached
        // the coordinator through instead
        assert_eq!(
            substitute_wildcard_host("0.0.0.0:9001".to_string(), "10.0.0.1"),
            "10.0.0.1:9001"
        );
        assert_eq!(
            substitute_wildcard_host("[::]:9001".to_string(), "[fd00::1]"),
            "[fd00::1]:9001"
        );
        // real addresses pass through untouched
        assert_eq!(
            substitute_wildcard_host("10.0.0.7:9001".to_string(), "10.0.0.1"),
            "10.0.0.7:9001"
        );
        assert_eq!(
            substitute_wildcard_host("[::1]:9001".to_string(), "ignored"),
            "[::1]:9001"
        );
    }

    #[test]
    fn client_listener_family_follows_the_coordinator() {
        assert_eq!(wildcard_listen_addr("127.0.0.1"), "0.0.0.0:0");
        assert_eq!(wildcard_listen_addr("somehost"), "0.0.0.0:0");
        assert_eq!(wildcard_listen_addr("[::1]"), "[::]:0");
    }
}
