//! The threaded cluster engine: one OS thread per rank.
//!
//! [`run_threaded`] is the shared-nothing counterpart of the lock-step
//! `training::sim::run_lockstep`: it builds one sparsifier replica per
//! rank, wires the ranks together with a [`LocalTransport`], launches
//! each [`SimWorker`] on its own scoped thread, and merges the per-rank
//! records into one [`Trace`] (rank 0's records — all deterministic
//! fields are identical across ranks, and `t_select` is already the
//! all-gathered cluster max).
//!
//! [`run_rank_on_transport`] is the multi-process form: it runs exactly
//! one rank's [`SimWorker`] over an externally-built transport (e.g. a
//! [`crate::cluster::net::TcpTransport`]); the `exdyna launch`
//! subcommand calls it once per process.

use crate::cluster::transport::{Endpoint, LocalTransport, Transport};
use crate::cluster::worker::SimWorker;
use crate::error::{Error, Result};
use crate::grad::synth::SynthGen;
use crate::metrics::{IterRecord, Trace};
use crate::obs::{FlightRecorder, ObsCfg, SpanTracer};
use crate::sparsifiers::Sparsifier;
use crate::training::sim::{SimCfg, SparsifierFactory};
use std::time::Instant;

/// When one rank fails, its peers fail their rendezvous with a
/// poisoned-transport fault — the typed [`Error::PeerLost`] /
/// [`Error::Poisoned`] (or, from older string paths, an `Invariant`
/// mentioning "poisoned"); surface the original failure instead of
/// whichever rank happened to be joined first.
pub(crate) fn pick_root_cause(errors: Vec<Error>) -> Error {
    let mut fallback = None;
    for e in errors {
        let is_poison = e.is_membership_fault()
            || matches!(&e, Error::Invariant(m) if m.contains("poisoned"));
        if !is_poison {
            return e;
        }
        fallback = Some(e);
    }
    fallback.expect("pick_root_cause called with no errors")
}

/// Facts about one threaded run, for tests and diagnostics.
#[derive(Clone, Copy, Debug)]
pub struct ClusterStats {
    /// Ranks launched.
    pub n_ranks: usize,
    /// Distinct worker OS threads observed (must equal `n_ranks`).
    pub distinct_threads: usize,
}

/// Run one rank of a (typically multi-process) cluster over `transport`.
/// Every deterministic trace field is identical on all ranks and
/// `t_select` is the all-gathered max, so each rank returns the same
/// merged cluster trace; rank 0's copy is canonical. A failed worker
/// poisons the transport so peers error out instead of hanging.
pub fn run_rank_on_transport(
    gen: &SynthGen,
    make_sparsifier: &SparsifierFactory,
    cfg: &SimCfg,
    rank: usize,
    transport: &dyn Transport,
) -> Result<Trace> {
    run_rank_on_transport_obs(gen, make_sparsifier, cfg, rank, transport, &ObsCfg::default())
}

/// [`run_rank_on_transport`] with observability: tags the process-wide
/// logger with this rank, attaches a [`FlightRecorder`] to the
/// transport when asked, and threads a [`SpanTracer`] through the
/// worker, writing this rank's `.rank<R>.part` span file on success.
/// Merging the parts is the caller's job (the `launch` parent, which
/// outlives all ranks). With `obs` fully off this is exactly
/// [`run_rank_on_transport`]: nothing is constructed, nothing recorded.
pub fn run_rank_on_transport_obs(
    gen: &SynthGen,
    make_sparsifier: &SparsifierFactory,
    cfg: &SimCfg,
    rank: usize,
    transport: &dyn Transport,
    obs: &ObsCfg,
) -> Result<Trace> {
    let n = cfg.n_ranks;
    if n == 0 {
        return Err(Error::invalid("n_ranks must be >= 1"));
    }
    if n != transport.n_ranks() {
        return Err(Error::invalid(format!(
            "config says {n} ranks but the transport spans {}",
            transport.n_ranks()
        )));
    }
    if rank >= n {
        return Err(Error::invalid(format!("rank {rank} out of range (n = {n})")));
    }
    if obs.is_active() {
        crate::obs::log::set_rank(rank);
    }
    if obs.flight_recorder {
        transport.attach_flight_recorder(rank, FlightRecorder::new(rank));
    }
    let tracer = obs.tracing().then(|| SpanTracer::new(rank));
    let sp = make_sparsifier(gen.n_g(), n)?;
    let name = sp.name();
    let mut trace = Trace::new(&name, &gen.model.name, n);
    trace.pipelined = cfg.pipeline;
    // a panicking worker must poison the transport too, not just an Err
    let _guard = crate::cluster::transport::AbortOnPanic(transport);
    let ep = Endpoint::new(rank, transport);
    let worker = SimWorker::new(rank, sp, gen, cfg, ep).with_tracer(tracer);
    let out = worker.run_traced();
    if out.is_err() {
        // don't leave remote peers blocked at the rendezvous
        transport.abort();
    }
    let (records, tracer) = out?;
    if let (Some(base), Some(tr)) = (obs.trace_path.as_deref(), tracer.as_ref()) {
        tr.write_part(base)?;
    }
    for rec in records {
        trace.push(rec);
    }
    Ok(trace)
}

/// Run the simulated trainer with one thread per rank; returns the trace.
pub fn run_threaded(
    gen: &SynthGen,
    make_sparsifier: &SparsifierFactory,
    cfg: &SimCfg,
) -> Result<Trace> {
    run_threaded_with_stats(gen, make_sparsifier, cfg).map(|(trace, _)| trace)
}

/// [`run_threaded`] plus [`ClusterStats`] (used by the parity tests to
/// prove real per-rank threading).
pub fn run_threaded_with_stats(
    gen: &SynthGen,
    make_sparsifier: &SparsifierFactory,
    cfg: &SimCfg,
) -> Result<(Trace, ClusterStats)> {
    run_threaded_with_stats_obs(gen, make_sparsifier, cfg, &ObsCfg::default())
}

/// [`run_threaded`] with observability switched on: every rank gets a
/// [`SpanTracer`] against one shared origin (so the merged timeline's
/// lanes align exactly) and, when asked, a [`FlightRecorder`]; after
/// the join the engine itself merges the span part files into the final
/// chrome-trace JSON, since no launch parent outlives these ranks.
pub fn run_threaded_obs(
    gen: &SynthGen,
    make_sparsifier: &SparsifierFactory,
    cfg: &SimCfg,
    obs: &ObsCfg,
) -> Result<Trace> {
    run_threaded_with_stats_obs(gen, make_sparsifier, cfg, obs).map(|(trace, _)| trace)
}

/// The one true threaded-engine body: [`run_threaded_with_stats`] and
/// [`run_threaded_obs`] are thin wrappers over this.
pub fn run_threaded_with_stats_obs(
    gen: &SynthGen,
    make_sparsifier: &SparsifierFactory,
    cfg: &SimCfg,
    obs: &ObsCfg,
) -> Result<(Trace, ClusterStats)> {
    let n = cfg.n_ranks;
    if n == 0 {
        return Err(Error::invalid("n_ranks must be >= 1"));
    }
    let n_g = gen.n_g();
    // replicas are built on the launcher thread (the factory need not be
    // Sync), then each is moved onto its rank's thread
    let sparsifiers: Vec<Box<dyn Sparsifier>> = (0..n)
        .map(|_| make_sparsifier(n_g, n))
        .collect::<Result<_>>()?;
    let name = sparsifiers[0].name();
    let mut trace = Trace::new(&name, &gen.model.name, n);
    trace.pipelined = cfg.pipeline;

    let transport = LocalTransport::new(n);
    if obs.flight_recorder {
        for rank in 0..n {
            transport.attach_flight_recorder(rank, FlightRecorder::new(rank));
        }
    }
    // one origin for every rank's tracer: lanes in the merged timeline
    // share t=0
    let origin = Instant::now();
    type RankOut = (std::thread::ThreadId, Vec<IterRecord>, Option<SpanTracer>);
    let results: Vec<Result<RankOut>> = std::thread::scope(|scope| {
        let transport = &transport;
        let mut handles = Vec::with_capacity(n);
        for (rank, sp) in sparsifiers.into_iter().enumerate() {
            let tracer = obs.tracing().then(|| SpanTracer::with_origin(rank, origin));
            handles.push(scope.spawn(move || {
                // a panic (vs an Err) must also poison the transport,
                // or the sibling joins below would block forever
                let _guard =
                    crate::cluster::transport::AbortOnPanic(transport as &dyn Transport);
                let ep = Endpoint::new(rank, transport as &dyn Transport);
                let worker = SimWorker::new(rank, sp, gen, cfg, ep).with_tracer(tracer);
                let out = worker.run_traced();
                if out.is_err() {
                    // don't leave peers blocked at the rendezvous
                    transport.abort();
                }
                out.map(|(records, tracer)| (std::thread::current().id(), records, tracer))
            }));
        }
        handles
            .into_iter()
            .map(|h| {
                h.join()
                    .unwrap_or_else(|_| Err(Error::invariant("cluster worker panicked")))
            })
            .collect()
    });
    let mut per_rank = Vec::with_capacity(n);
    let mut errors = Vec::new();
    for r in results {
        match r {
            Ok(v) => per_rank.push(v),
            Err(e) => errors.push(e),
        }
    }
    if !errors.is_empty() {
        return Err(pick_root_cause(errors));
    }

    if let Some(base) = obs.trace_path.as_deref() {
        for (_, _, tracer) in per_rank.iter() {
            if let Some(tr) = tracer {
                tr.write_part(base)?;
            }
        }
        crate::obs::trace::merge(base, n)?;
    }

    // ThreadId is not Ord; count distinct ids by linear scan (n is small)
    let mut distinct: Vec<std::thread::ThreadId> = Vec::with_capacity(n);
    for (id, _, _) in per_rank.iter() {
        if !distinct.contains(id) {
            distinct.push(*id);
        }
    }
    let stats = ClusterStats {
        n_ranks: n,
        distinct_threads: distinct.len(),
    };

    // rank 0's records are the cluster trace (see SimWorker::run docs)
    let (_, records, _) = per_rank.into_iter().next().expect("n >= 1");
    for rec in records {
        trace.push(rec);
    }
    Ok((trace, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{ExDyna, ExDynaCfg};
    use crate::grad::synth::{DecayCfg, SynthModel};

    #[test]
    fn threaded_run_produces_full_trace_on_worker_threads() {
        let n = 3;
        let model = SynthModel::profile("t", 48_000, 6, 5, DecayCfg::default());
        let gen = SynthGen::new(model, n, 0.5, 17, false);
        let cfg = SimCfg {
            n_ranks: n,
            iters: 8,
            compute_s: 0.01,
            ..Default::default()
        };
        let (trace, stats) = run_threaded_with_stats(
            &gen,
            &|n_g, nr| Ok(Box::new(ExDyna::new(n_g, nr, ExDynaCfg::default_for(nr))?)),
            &cfg,
        )
        .unwrap();
        assert_eq!(trace.records.len(), 8);
        assert_eq!(trace.n_ranks, n);
        assert_eq!(stats.n_ranks, n);
        assert_eq!(stats.distinct_threads, n, "one OS thread per rank");
        for r in &trace.records {
            assert!(r.k_actual > 0);
            assert!(r.t_comm > 0.0);
        }
    }

    #[test]
    fn rank_on_transport_matches_threaded_trace() {
        // run every rank of a LocalTransport cluster through the
        // multi-process entry point; each rank's merged trace must agree
        // with run_threaded on all deterministic fields
        let n = 3;
        let model = SynthModel::profile("t", 48_000, 6, 5, DecayCfg::default());
        let gen = SynthGen::new(model, n, 0.5, 17, false);
        let cfg = SimCfg {
            n_ranks: n,
            iters: 6,
            compute_s: 0.01,
            ..Default::default()
        };
        let mk = |n_g: usize, nr: usize| -> crate::error::Result<Box<dyn crate::sparsifiers::Sparsifier>> {
            Ok(Box::new(ExDyna::new(n_g, nr, ExDynaCfg::default_for(nr))?))
        };
        let reference = run_threaded(&gen, &mk, &cfg).unwrap();
        let tp = LocalTransport::new(n);
        let traces: Vec<Trace> = std::thread::scope(|scope| {
            let tp = &tp;
            let gen = &gen;
            let cfg = &cfg;
            let handles: Vec<_> = (0..n)
                .map(|rank| {
                    scope.spawn(move || {
                        run_rank_on_transport(gen, &mk, cfg, rank, tp as &dyn Transport)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap().unwrap()).collect()
        });
        for (rank, t) in traces.iter().enumerate() {
            assert_eq!(t.records.len(), reference.records.len(), "rank {rank}");
            for (a, b) in t.records.iter().zip(reference.records.iter()) {
                assert_eq!(a.k_actual, b.k_actual, "rank {rank} t={}", a.t);
                assert_eq!(a.k_sum, b.k_sum, "rank {rank} t={}", a.t);
                assert_eq!(a.delta.to_bits(), b.delta.to_bits(), "rank {rank} t={}", a.t);
                assert_eq!(
                    a.t_comm.to_bits(),
                    b.t_comm.to_bits(),
                    "rank {rank} t={}",
                    a.t
                );
            }
        }
        // bad rank / world mismatches are rejected up front
        assert!(run_rank_on_transport(&gen, &mk, &cfg, n, &LocalTransport::new(n)).is_err());
        let mut bad = cfg;
        bad.n_ranks = n + 1;
        assert!(run_rank_on_transport(&gen, &mk, &bad, 0, &LocalTransport::new(n)).is_err());
    }

    #[test]
    fn obs_run_merges_spans_and_leaves_the_trace_bit_identical() {
        let n = 2;
        let model = SynthModel::profile("t", 24_000, 4, 5, DecayCfg::default());
        let gen = SynthGen::new(model, n, 0.5, 17, false);
        let cfg = SimCfg {
            n_ranks: n,
            iters: 4,
            compute_s: 0.01,
            ..Default::default()
        };
        let mk = |n_g: usize,
                  nr: usize|
         -> crate::error::Result<Box<dyn crate::sparsifiers::Sparsifier>> {
            Ok(Box::new(ExDyna::new(n_g, nr, ExDynaCfg::default_for(nr))?))
        };
        let plain = run_threaded(&gen, &mk, &cfg).unwrap();
        let dir = std::env::temp_dir().join(format!("exdyna_engine_obs_{}", std::process::id()));
        let base = dir.join("run.trace.json");
        let obs = ObsCfg {
            trace_path: Some(base.clone()),
            flight_recorder: true,
            ..ObsCfg::default()
        };
        let traced = run_threaded_obs(&gen, &mk, &cfg, &obs).unwrap();
        // observability must not perturb the deterministic trace
        assert_eq!(plain.records.len(), traced.records.len());
        for (a, b) in plain.records.iter().zip(traced.records.iter()) {
            assert_eq!(a.k_actual, b.k_actual);
            assert_eq!(a.delta.to_bits(), b.delta.to_bits());
            assert_eq!(a.t_comm.to_bits(), b.t_comm.to_bits());
        }
        // the engine merged the part files into one chrome-trace doc
        let doc = std::fs::read_to_string(&base).unwrap();
        assert!(doc.starts_with("{\"traceEvents\":["), "{doc}");
        assert!(doc.contains("\"pid\":0") && doc.contains("\"pid\":1"));
        assert!(doc.contains("\"name\":\"compute\"") && doc.contains("\"name\":\"round\""));
        assert!(!crate::obs::SpanTracer::part_path(&base, 0).exists());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn zero_ranks_rejected() {
        let model = SynthModel::profile("t", 4_096, 3, 5, DecayCfg::default());
        let gen = SynthGen::new(model, 1, 0.5, 17, false);
        let cfg = SimCfg {
            n_ranks: 0,
            iters: 1,
            ..Default::default()
        };
        let res = run_threaded(
            &gen,
            &|n_g, nr| Ok(Box::new(ExDyna::new(n_g, nr.max(1), ExDynaCfg::default_for(1))?)),
            &cfg,
        );
        assert!(res.is_err());
    }

    #[test]
    fn root_cause_preferred_over_poison_noise() {
        let errs = vec![
            Error::invariant("transport poisoned by a failed worker"),
            Error::invalid("the real problem"),
            Error::invariant("transport poisoned by a failed worker"),
        ];
        let picked = pick_root_cause(errs);
        assert!(picked.to_string().contains("the real problem"), "{picked}");
        // all-poisoned still yields an error
        let picked = pick_root_cause(vec![Error::invariant(
            "transport poisoned by a failed worker",
        )]);
        assert!(picked.to_string().contains("poisoned"));
        // the typed membership faults are poison noise too
        let errs = vec![
            Error::peer_lost(2, 9),
            Error::invalid("the real problem"),
            Error::poisoned(9),
        ];
        let picked = pick_root_cause(errs);
        assert!(picked.to_string().contains("the real problem"), "{picked}");
        let picked = pick_root_cause(vec![Error::peer_lost(1, 3)]);
        assert!(picked.to_string().contains("rank 1"), "{picked}");
    }

    #[test]
    fn failing_factory_surfaces_before_launch() {
        let model = SynthModel::profile("t", 4_096, 3, 5, DecayCfg::default());
        let gen = SynthGen::new(model, 2, 0.5, 17, false);
        let cfg = SimCfg {
            n_ranks: 2,
            iters: 1,
            ..Default::default()
        };
        let res = run_threaded(
            &gen,
            &|_, _| Err(crate::error::Error::invalid("boom")),
            &cfg,
        );
        assert!(res.is_err());
    }
}
