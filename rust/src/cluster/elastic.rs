//! Epoch-based elastic membership: survivors of a dead rank re-form
//! the cluster instead of aborting, and a restarted rank rejoins at an
//! epoch boundary.
//!
//! The moving parts:
//!
//! * [`Membership`] — what a rank needs from its cluster while running:
//!   an iteration-start [`Membership::probe`] (does anyone want a
//!   reform?), a [`Membership::reform`] that blocks until the next
//!   epoch is formed and hands back a fresh [`Seat`], and an
//!   [`Membership::on_chaos_kill`] notification for the injected-death
//!   path.
//! * [`ElasticCluster`] — the in-process implementation (one OS thread
//!   per rank over [`LocalTransport`] or [`RingLocal`]): a mutex/condvar
//!   barrier where the survivors of a fault deposit their claims and
//!   the last arrival builds the next epoch's transport. Because the
//!   whole cluster shares one address space it can also bank a killed
//!   rank's error-feedback accumulator and hand it back on rejoin —
//!   EF mass is conserved across an in-process kill/rejoin cycle.
//! * [`SocketMember`] — the one-process-per-rank implementation,
//!   delegating to the wire protocol in
//!   [`net::elastic`](crate::cluster::net::elastic). Any member can be
//!   the coordinator: original rank 0 starts as one (it binds the
//!   bootstrap rendezvous listener), every other member pre-binds a
//!   standby listener and is seated with the epoch's succession table.
//!   A restarted process lost its memory, so a socket rejoin restores
//!   only the sparsifier snapshot carried by the Welcome, not the EF
//!   accumulator.
//!
//! # The promotion state machine
//!
//! A [`SocketMember`] is always in exactly one of two roles, and only
//! ever moves one way:
//!
//! ```text
//!   member ──(walk finds every predecessor dead)──▶ coordinator
//! ```
//!
//! * **member** (`coord: None`): holds a pre-bound standby listener
//!   whose address rides every succession table. On a membership fault
//!   it walks the table with
//!   [`reform_via_succession`]: the first *live* entry ahead of it is
//!   the rightful coordinator (a refused dial can only mean death —
//!   standbys outlive every epoch), so it claims its seat there.
//! * **coordinator** (`coord: Some`): answers claims on its listener —
//!   the bootstrap rendezvous socket for original rank 0, the activated
//!   standby for a promoted member. Claims from ranks *below* the
//!   sitting coordinator are rejected, so the seat-0 invariant (the
//!   coordinator is always the lowest live original rank) survives even
//!   a dead rank 0 coming back from the grave.
//!
//! The walk returns [`ReformOutcome::Promote`] only after *observing*
//! a refused dial to every candidate ahead — attribution alone never
//! promotes — which makes the promotion unique: for any set of deaths,
//! exactly one survivor (the lowest, see [`elect_coordinator`]) sees an
//! all-dead prefix. Everyone else parks a claim at that survivor's
//! standby and is seated when it promotes and re-forms.
//! * [`run_elastic_seat`] — one rank's recovery loop: run
//!   [`SimWorker::run_state`] over the current seat; on a membership
//!   fault ([`Error::is_membership_fault`] or
//!   [`Error::looks_like_peer_loss`]) poison the old transport, export
//!   the sparsifier state, re-form, and resume from
//!   [`WorkerState::start_t`] — the error carry and replica feedback of
//!   every completed iteration survive, so no threshold step is ever
//!   replayed.
//! * [`run_elastic_threaded`] — the thread-per-rank driver (the
//!   `sim --elastic` path), chaos injection included.
//!
//! Epoch fencing is structural: every re-formation builds a brand-new
//! epoch-stamped transport, so no data frame needs an epoch tag and the
//! round generation restarts at 0 per epoch. The per-epoch world is
//! re-tiled over the survivors ([`Sparsifier::reform`] →
//! [`PartitionLayout::retile`](crate::coordinator::PartitionLayout::retile)),
//! while each worker's *data* stream stays pinned to its original rank
//! ([`SimWorker::with_data_rank`]) — shrinking the world changes who
//! owns which gradient partition, never which gradients exist.

use crate::cluster::net::elastic::{
    bind_standby, join_ring, join_star, reform_ring_client, reform_star_client,
    reform_via_succession, EpochCoordinator, EpochSeat, ReformOutcome,
};
use crate::cluster::net::NetCfg;
use crate::obs::{FlightRecorder, RecKind};
use crate::cluster::ring_local::RingLocal;
use crate::cluster::transport::{AbortOnPanic, Endpoint, LocalTransport, Transport};
use crate::cluster::worker::{SimWorker, WorkerState};
use crate::error::{Error, Result};
use crate::grad::synth::SynthGen;
use crate::metrics::{IterRecord, Trace};
use crate::sparsifiers::Sparsifier;
use crate::training::sim::{SimCfg, SparsifierFactory};
use std::collections::{BTreeMap, BTreeSet};
use std::sync::{Condvar, Mutex};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Elastic-membership knobs (`--elastic`, `--chaos-kill-at`).
#[derive(Clone, Debug)]
pub struct ElasticCfg {
    /// Recover from membership faults instead of aborting the run.
    pub enabled: bool,
    /// Deterministic fault injection schedule: `(iteration, original
    /// rank)` sites at which a rank dies ([`Error::ChaosKilled`]) — the
    /// crash is simulated, so a victim never sends abort frames itself.
    /// Empty = fault-free.
    pub chaos_kill_at: Vec<(usize, usize)>,
    /// Upper bound on re-formations before a rank gives up (a backstop
    /// against a flapping cluster re-forming forever).
    pub max_epochs: u64,
    /// How long a re-formation waits for missing survivors before
    /// declaring them dead.
    pub grace: Duration,
}

impl Default for ElasticCfg {
    fn default() -> Self {
        ElasticCfg {
            enabled: false,
            chaos_kill_at: Vec::new(),
            max_epochs: 8,
            grace: Duration::from_secs(2),
        }
    }
}

/// Parse a `--chaos-kill-at` schedule: comma-separated `ITER:RANK`
/// sites (e.g. `5:2` or `4:0,8:1`). A rank may appear at most once —
/// a chaos-killed process never comes back to be killed again.
pub fn parse_kill_at(s: &str) -> Result<Vec<(usize, usize)>> {
    let mut sites: Vec<(usize, usize)> = Vec::new();
    for part in s.split(',') {
        let bad = || {
            Error::invalid(format!(
                "--chaos-kill-at wants a schedule of ITER:RANK sites \
                 (e.g. 5:2 or 4:0,8:1), got '{part}' in '{s}'"
            ))
        };
        let (t, r) = part.split_once(':').ok_or_else(bad)?;
        let site: (usize, usize) = (
            t.trim().parse().map_err(|_| bad())?,
            r.trim().parse().map_err(|_| bad())?,
        );
        if sites.iter().any(|&(_, rank)| rank == site.1) {
            return Err(Error::invalid(format!(
                "--chaos-kill-at names rank {} twice in '{s}': a killed \
                 rank cannot die again",
                site.1
            )));
        }
        sites.push(site);
    }
    Ok(sites)
}

/// The coordinator a survivor set elects: the lowest original rank in
/// `world` that is not in `dead`. Deterministic (a pure minimum — every
/// survivor computes the same answer from the same inputs) and total
/// (any world with at least one survivor elects someone; a member is
/// excluded only by being dead). This is the function the socket
/// succession walk realizes over the wire, one refused dial per dead
/// predecessor.
pub fn elect_coordinator(world: &[u32], dead: &BTreeSet<u32>) -> Option<u32> {
    world.iter().copied().filter(|r| !dead.contains(r)).min()
}

/// Everything one rank needs to run one epoch: its dense rank, the
/// epoch's membership, the freshly built transport, and (for a
/// late joiner) the state restored at the boundary.
pub struct Seat {
    /// The membership epoch this seat belongs to.
    pub epoch: u64,
    /// This rank's dense seat index within the epoch.
    pub rank: usize,
    /// Original ranks of every member, indexed by dense rank.
    pub world: Vec<u32>,
    /// Iteration the epoch resumes at.
    pub resume_t: usize,
    /// The epoch's transport (built fresh per epoch — epoch fencing is
    /// structural, see the module docs).
    pub transport: Arc<dyn Transport>,
    /// Sparsifier state snapshot to import (late joiners only).
    pub sp_import: Option<Vec<u8>>,
    /// Error-feedback accumulator to restore (in-process rejoin only;
    /// a restarted process has genuinely lost its accumulator).
    pub err_restore: Option<Vec<f32>>,
}

impl From<EpochSeat> for Seat {
    fn from(s: EpochSeat) -> Seat {
        Seat {
            epoch: s.epoch,
            rank: s.rank,
            world: s.world,
            resume_t: s.resume_t as usize,
            transport: s.transport,
            sp_import: (!s.snapshot.is_empty()).then_some(s.snapshot),
            err_restore: None,
        }
    }
}

/// A rank's view of its elastic cluster while running.
pub trait Membership: Send + Sync {
    /// Blocks until the next epoch is formed and this rank is seated.
    /// `next_t` is where this rank's [`WorkerState`] will resume;
    /// `export` is its sparsifier snapshot (forwarded to joiners by
    /// whichever survivor the implementation elects as donor); `lost`
    /// is the original rank this rank believes died, when the fault
    /// carried an attribution ([`Error::PeerLost`]).
    fn reform(
        &self,
        orig_rank: usize,
        next_t: usize,
        export: Option<Vec<u8>>,
        lost: Option<u32>,
    ) -> Result<Seat>;

    /// The injected death fired on `orig_rank`: record whatever the
    /// implementation can salvage (the in-process cluster banks the EF
    /// accumulator and poisons the shared transport on the victim's
    /// behalf; the socket implementation does nothing — dropped sockets
    /// are the death notice).
    fn on_chaos_kill(&self, orig_rank: usize, err: &[f32]);

    /// Iteration-start probe: `Err(Error::Reform)` when the cluster
    /// should re-form at this boundary (e.g. a joiner is parked).
    fn probe(&self, orig_rank: usize, t: usize) -> Result<()>;
}

/// Which in-process transport an [`ElasticCluster`] re-forms.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElasticFlavor {
    /// [`LocalTransport`] (mutex/condvar slot board).
    Local,
    /// [`RingLocal`] (in-process ring twin).
    Ring,
}

/// A formed seat waiting for its rank to pick it up.
struct PendingSeat {
    epoch: u64,
    rank: usize,
    world: Vec<u32>,
    resume_t: usize,
    transport: Arc<dyn Transport>,
    sp_import: Option<Vec<u8>>,
    err_restore: Option<Vec<f32>>,
}

impl PendingSeat {
    fn into_seat(self) -> Seat {
        Seat {
            epoch: self.epoch,
            rank: self.rank,
            world: self.world,
            resume_t: self.resume_t,
            transport: self.transport,
            sp_import: self.sp_import,
            err_restore: self.err_restore,
        }
    }
}

struct EState {
    epoch: u64,
    /// The elected coordinator ([`elect_coordinator`] over the current
    /// world) — tracked so a succession is observable in the twin too.
    coordinator: u32,
    /// Original ranks of the current epoch's members, sorted.
    world: Vec<u32>,
    /// The current epoch's transport (so a chaos kill can poison it on
    /// the victim's behalf — the in-process waits are untimed).
    transport: Arc<dyn Transport>,
    dead: BTreeSet<u32>,
    /// Ranks waiting to be seated at the next boundary.
    joiners: BTreeSet<u32>,
    /// Survivor claims for the pending re-formation: orig rank → the
    /// iteration it resumes at.
    arrived: BTreeMap<u32, usize>,
    /// Survivor sparsifier snapshots (donor source for joiners).
    exports: BTreeMap<u32, Vec<u8>>,
    /// Banked error-feedback accumulators of dead ranks, restored on
    /// rejoin so EF mass is conserved across a kill/rejoin cycle.
    err_bank: BTreeMap<u32, Vec<f32>>,
    /// Formed seats awaiting pickup, by original rank.
    seats: BTreeMap<u32, PendingSeat>,
}

/// In-process elastic membership: one shared barrier all rank threads
/// re-form through. See the module docs for the protocol.
pub struct ElasticCluster {
    flavor: ElasticFlavor,
    grace: Duration,
    /// Receive deadline for the [`RingLocal`] flavor (the local flavor's
    /// waits are untimed and rely on abort poisoning).
    ring_timeout: Duration,
    st: Mutex<EState>,
    cv: Condvar,
}

impl ElasticCluster {
    /// A cluster of `n` ranks at epoch 0.
    pub fn new(
        n: usize,
        flavor: ElasticFlavor,
        grace: Duration,
        ring_timeout: Duration,
    ) -> Result<Self> {
        if n == 0 {
            return Err(Error::invalid("world size must be >= 1"));
        }
        let transport = Self::build_transport(flavor, n, 0, ring_timeout);
        Ok(ElasticCluster {
            flavor,
            grace,
            ring_timeout,
            st: Mutex::new(EState {
                epoch: 0,
                coordinator: 0,
                world: (0..n as u32).collect(),
                transport,
                dead: BTreeSet::new(),
                joiners: BTreeSet::new(),
                arrived: BTreeMap::new(),
                exports: BTreeMap::new(),
                err_bank: BTreeMap::new(),
                seats: BTreeMap::new(),
            }),
            cv: Condvar::new(),
        })
    }

    fn build_transport(
        flavor: ElasticFlavor,
        n: usize,
        epoch: u64,
        ring_timeout: Duration,
    ) -> Arc<dyn Transport> {
        match flavor {
            ElasticFlavor::Local => Arc::new(LocalTransport::new_at_epoch(n, epoch)),
            ElasticFlavor::Ring => Arc::new(RingLocal::with_timeout_at_epoch(n, ring_timeout, epoch)),
        }
    }

    /// This rank's seat in the current (normally initial) epoch.
    pub fn initial_seat(&self, orig_rank: usize) -> Result<Seat> {
        let st = self.st.lock().unwrap();
        let orig = orig_rank as u32;
        let rank = st
            .world
            .iter()
            .position(|&r| r == orig)
            .ok_or_else(|| {
                Error::invalid(format!(
                    "rank {orig_rank} is not a member (world {:?})",
                    st.world
                ))
            })?;
        Ok(Seat {
            epoch: st.epoch,
            rank,
            world: st.world.clone(),
            resume_t: 0,
            transport: st.transport.clone(),
            sp_import: None,
            err_restore: None,
        })
    }

    /// Rejoin a previously dead rank at the next epoch boundary. Live
    /// members learn of the registration through their next
    /// [`Membership::probe`] and force a re-formation; this call blocks
    /// until seated (with the banked EF accumulator and the donor's
    /// sparsifier snapshot restored) or the join window runs out.
    pub fn join(&self, orig_rank: usize) -> Result<Seat> {
        let me = orig_rank as u32;
        let mut st = self.st.lock().unwrap();
        if st.world.contains(&me) && !st.dead.contains(&me) {
            return Err(Error::invalid(format!(
                "rank {orig_rank} is already a live member"
            )));
        }
        st.joiners.insert(me);
        self.cv.notify_all();
        let deadline = Instant::now() + self.grace.saturating_mul(4);
        loop {
            if let Some(ps) = st.seats.remove(&me) {
                return Ok(ps.into_seat());
            }
            let now = Instant::now();
            if now >= deadline {
                st.joiners.remove(&me);
                return Err(Error::protocol(
                    "elastic join timed out waiting for an epoch boundary",
                ));
            }
            let (g, _) = self.cv.wait_timeout(st, deadline - now).unwrap();
            st = g;
        }
    }

    /// Build the next epoch from the claims on the table: members are
    /// exactly the arrived survivors plus the registered joiners.
    /// Requires the lock; wake the waiters after.
    fn form(&self, st: &mut EState) {
        let mut world: Vec<u32> = st
            .arrived
            .keys()
            .copied()
            .chain(st.joiners.iter().copied())
            .collect();
        world.sort_unstable();
        world.dedup();
        let epoch = st.epoch + 1;
        let n = world.len();
        let transport = Self::build_transport(self.flavor, n, epoch, self.ring_timeout);
        let resume_t = st.arrived.values().copied().max().unwrap_or(0);
        // joiners inherit state from the lowest-ranked survivor that
        // offered a snapshot (BTreeMap keys iterate ascending)
        let donor: Option<Vec<u8>> = st.arrived.keys().find_map(|r| st.exports.get(r).cloned());
        for (idx, &orig) in world.iter().enumerate() {
            let fresh = st.joiners.contains(&orig);
            st.seats.insert(
                orig,
                PendingSeat {
                    epoch,
                    rank: idx,
                    world: world.clone(),
                    resume_t,
                    transport: transport.clone(),
                    sp_import: if fresh { donor.clone() } else { None },
                    err_restore: if fresh { st.err_bank.remove(&orig) } else { None },
                },
            );
            st.dead.remove(&orig);
        }
        crate::log_info!(
            "elastic",
            "cluster re-formed: epoch {epoch} world {world:?} resume_t {resume_t}"
        );
        if let Some(coord) = elect_coordinator(&world, &BTreeSet::new()) {
            if coord != st.coordinator {
                crate::log_info!(
                    "elastic",
                    "CoordinatorPromoted: rank {coord} takes over from rank {} at \
                     epoch {epoch}",
                    st.coordinator
                );
                st.coordinator = coord;
            }
        }
        st.epoch = epoch;
        st.world = world;
        st.transport = transport;
        st.arrived.clear();
        st.exports.clear();
        st.joiners.clear();
    }
}

impl Membership for ElasticCluster {
    fn reform(
        &self,
        orig_rank: usize,
        next_t: usize,
        export: Option<Vec<u8>>,
        lost: Option<u32>,
    ) -> Result<Seat> {
        let me = orig_rank as u32;
        let mut st = self.st.lock().unwrap();
        // arriving proves liveness, whatever anyone reported
        st.dead.remove(&me);
        if let Some(l) = lost {
            if l != me {
                st.dead.insert(l);
            }
        }
        st.arrived.insert(me, next_t);
        if let Some(b) = export {
            st.exports.insert(me, b);
        }
        self.cv.notify_all();
        let deadline = Instant::now() + self.grace;
        loop {
            if let Some(ps) = st.seats.remove(&me) {
                return Ok(ps.into_seat());
            }
            let survivors: Vec<u32> = st
                .world
                .iter()
                .copied()
                .filter(|r| !st.dead.contains(r))
                .collect();
            if !survivors.is_empty() && survivors.iter().all(|r| st.arrived.contains_key(r)) {
                self.form(&mut st);
                self.cv.notify_all();
                continue;
            }
            let now = Instant::now();
            if now >= deadline {
                // grace ran out: whoever never arrived is dead — form
                // the epoch from the claims actually on the table
                let missing: Vec<u32> = survivors
                    .into_iter()
                    .filter(|r| !st.arrived.contains_key(r))
                    .collect();
                for r in missing {
                    st.dead.insert(r);
                }
                self.form(&mut st);
                self.cv.notify_all();
                continue;
            }
            let (g, _) = self.cv.wait_timeout(st, deadline - now).unwrap();
            st = g;
        }
    }

    fn on_chaos_kill(&self, orig_rank: usize, err: &[f32]) {
        let me = orig_rank as u32;
        let mut st = self.st.lock().unwrap();
        st.dead.insert(me);
        st.arrived.remove(&me);
        if !err.is_empty() {
            st.err_bank.insert(me, err.to_vec());
        }
        // a crashed rank sends no abort frames, but the in-process
        // waits are untimed: poison on the victim's behalf so survivors
        // observe the death instead of blocking forever
        match st.world.iter().position(|&r| r == me) {
            Some(rank) => st.transport.abort_from(rank),
            None => st.transport.abort(),
        }
        self.cv.notify_all();
    }

    fn probe(&self, _orig_rank: usize, _t: usize) -> Result<()> {
        let st = self.st.lock().unwrap();
        if st.joiners.is_empty() {
            Ok(())
        } else {
            Err(Error::Reform {
                epoch: st.epoch + 1,
            })
        }
    }
}

struct SockState {
    /// `Some` while this member is the coordinator — the rendezvous
    /// listener (bootstrap or activated standby) and its parked claims.
    coord: Option<EpochCoordinator>,
    /// The pre-bound standby listener (members only; taken on
    /// promotion, `None` once this member coordinates).
    standby: Option<std::net::TcpListener>,
    /// The standby listener's advertised port (0 on the coordinator).
    standby_port: u16,
    epoch: u64,
    world: Vec<u32>,
    /// The current epoch's succession table, seat-aligned with `world`.
    succession: Vec<String>,
}

/// One process's membership handle in a socket cluster (star or ring),
/// delegating to the wire protocol in
/// [`net::elastic`](crate::cluster::net::elastic). Symmetric: any
/// member can be promoted to coordinator (see the module docs).
pub struct SocketMember {
    cfg: NetCfg,
    ring: bool,
    grace: Duration,
    flight: Option<Arc<FlightRecorder>>,
    st: Mutex<SockState>,
}

impl SocketMember {
    /// Original rank 0: bind the bootstrap rendezvous listener and form
    /// the initial epoch.
    pub fn coordinator(
        n: usize,
        cfg: &NetCfg,
        ring: bool,
        grace: Duration,
    ) -> Result<(Self, Seat)> {
        let mut coord = EpochCoordinator::bind(cfg, grace)?;
        let es = if ring {
            coord.form_initial_ring(n)?
        } else {
            coord.form_initial_star(n)?
        };
        let m = SocketMember {
            cfg: cfg.clone(),
            ring,
            grace,
            flight: None,
            st: Mutex::new(SockState {
                coord: Some(coord),
                standby: None,
                standby_port: 0,
                epoch: 0,
                world: es.world.clone(),
                succession: es.succession.clone(),
            }),
        };
        Ok((m, es.into()))
    }

    /// A non-zero original rank: pre-bind the standby listener, then
    /// claim the epoch-0 seat over the same `HelloEpoch` exchange every
    /// later epoch uses — the succession table rides the first Welcome.
    pub fn client(
        n: usize,
        orig_rank: usize,
        cfg: &NetCfg,
        ring: bool,
        grace: Duration,
    ) -> Result<(Self, Seat)> {
        if orig_rank == 0 {
            return Err(Error::invalid(
                "original rank 0 is the coordinator; use SocketMember::coordinator",
            ));
        }
        if orig_rank >= n {
            return Err(Error::invalid(format!(
                "original rank {orig_rank} is outside the initial world of {n}"
            )));
        }
        let (standby, standby_port) = bind_standby(cfg)?;
        let es = if ring {
            reform_ring_client(cfg, 0, orig_rank as u32, 0, standby_port)?
        } else {
            reform_star_client(cfg, 0, orig_rank as u32, 0, standby_port)?
        };
        let m = SocketMember {
            cfg: cfg.clone(),
            ring,
            grace,
            flight: None,
            st: Mutex::new(SockState {
                coord: None,
                standby: Some(standby),
                standby_port,
                epoch: 0,
                world: es.world.clone(),
                succession: es.succession.clone(),
            }),
        };
        Ok((m, es.into()))
    }

    /// A restarted process with no seat yet: pre-bind a standby, dial
    /// the coordinator, and wait out the next epoch boundary. The
    /// returned seat carries the donor's sparsifier snapshot (a
    /// restarted process has lost its own state).
    pub fn rejoin(
        orig_rank: usize,
        cfg: &NetCfg,
        ring: bool,
        grace: Duration,
    ) -> Result<(Self, Seat)> {
        let (standby, standby_port) = bind_standby(cfg)?;
        let es = if ring {
            join_ring(cfg, orig_rank as u32, standby_port)?
        } else {
            join_star(cfg, orig_rank as u32, standby_port)?
        };
        let m = SocketMember {
            cfg: cfg.clone(),
            ring,
            grace,
            flight: None,
            st: Mutex::new(SockState {
                coord: None,
                standby: Some(standby),
                standby_port,
                epoch: es.epoch,
                world: es.world.clone(),
                succession: es.succession.clone(),
            }),
        };
        Ok((m, es.into()))
    }

    /// Attach a flight recorder: promotion and dial-retry events are
    /// recorded alongside the transport's protocol events.
    pub fn with_flight(mut self, flight: Arc<FlightRecorder>) -> Self {
        self.flight = Some(flight);
        self
    }

    /// The original rank seated at seat 0 of the current world — the
    /// member that owns the run outputs (merged trace, metrics) when the
    /// run completes. Starts as rank 0; moves only on a succession.
    pub fn senior_rank(&self) -> u32 {
        let st = self.st.lock().unwrap();
        st.world.first().copied().unwrap_or(0)
    }
}

impl Membership for SocketMember {
    fn reform(
        &self,
        orig_rank: usize,
        next_t: usize,
        export: Option<Vec<u8>>,
        lost: Option<u32>,
    ) -> Result<Seat> {
        let mut st = self.st.lock().unwrap();
        let epoch = st.epoch + 1;
        let prev_world = st.world.clone();
        let snapshot = export.unwrap_or_default();
        let es = if let Some(coord) = st.coord.as_mut() {
            let known_dead: Vec<u32> = lost.into_iter().collect();
            if self.ring {
                coord.reform_ring(epoch, &prev_world, &known_dead, next_t as u64, &snapshot)?
            } else {
                coord.reform_star(epoch, &prev_world, &known_dead, next_t as u64, &snapshot)?
            }
        } else {
            // a member walks the succession table: the first live entry
            // ahead of it is the coordinator (old or freshly promoted);
            // an all-dead prefix means this member is next in line
            let outcome = reform_via_succession(
                &self.cfg,
                self.ring,
                epoch,
                orig_rank as u32,
                next_t as u64,
                st.standby_port,
                &prev_world,
                &st.succession,
                lost,
                self.flight.as_deref(),
            )?;
            match outcome {
                ReformOutcome::Seated(es) => es,
                ReformOutcome::Promote => {
                    let my_seat = prev_world
                        .iter()
                        .position(|&r| r == orig_rank as u32)
                        .expect("the walk verified this rank's seat");
                    let standby = st
                        .standby
                        .take()
                        .expect("a member that can promote holds its standby");
                    let advertised = st.succession[my_seat].clone();
                    let mut coord = EpochCoordinator::promote(
                        standby,
                        orig_rank as u32,
                        advertised,
                        &self.cfg,
                        self.grace,
                    );
                    st.standby_port = 0;
                    crate::log_info!(
                        "elastic",
                        "CoordinatorPromoted: rank {orig_rank} activates its standby \
                         as the epoch {epoch} rendezvous (old coordinator rank {} is \
                         dead)",
                        prev_world[0]
                    );
                    if let Some(fr) = &self.flight {
                        fr.record(RecKind::CoordinatorPromoted, 0, orig_rank as u64, epoch);
                    }
                    // the walk proved every predecessor dead; fold in
                    // the fault's own attribution too
                    let mut known_dead: Vec<u32> = prev_world[..my_seat].to_vec();
                    if let Some(l) = lost {
                        if !known_dead.contains(&l) {
                            known_dead.push(l);
                        }
                    }
                    let es = if self.ring {
                        coord.reform_ring(
                            epoch,
                            &prev_world,
                            &known_dead,
                            next_t as u64,
                            &snapshot,
                        )?
                    } else {
                        coord.reform_star(
                            epoch,
                            &prev_world,
                            &known_dead,
                            next_t as u64,
                            &snapshot,
                        )?
                    };
                    st.coord = Some(coord);
                    es
                }
            }
        };
        st.epoch = es.epoch;
        st.world = es.world.clone();
        st.succession = es.succession.clone();
        Ok(es.into())
    }

    fn on_chaos_kill(&self, _orig_rank: usize, _err: &[f32]) {
        // a simulated crash sends nothing — peers detect the death by
        // the dropped sockets, exactly like a real process death
    }

    fn probe(&self, _orig_rank: usize, _t: usize) -> Result<()> {
        let mut st = self.st.lock().unwrap();
        let next = st.epoch + 1;
        if let Some(coord) = st.coord.as_mut() {
            if coord.poll_join()? {
                return Err(Error::Reform { epoch: next });
            }
        }
        Ok(())
    }
}

/// One rank's elastic recovery loop over an initial [`Seat`]: run the
/// worker; on a membership fault poison the old transport, carry the
/// sparsifier (and export a snapshot for any joiner), re-form through
/// `home`, and resume from [`WorkerState::start_t`]. Returns the rank's
/// records on completion, the terminal error otherwise — the injected
/// chaos death surfaces as [`Error::ChaosKilled`].
pub fn run_elastic_seat(
    gen: &SynthGen,
    cfg: &SimCfg,
    orig_rank: usize,
    sp0: Box<dyn Sparsifier>,
    mut seat: Seat,
    home: &dyn Membership,
    ecfg: &ElasticCfg,
) -> Result<Vec<IterRecord>> {
    if cfg.pipeline {
        return Err(Error::invalid(
            "elastic membership requires the sequential loop; drop --pipeline",
        ));
    }
    let mut state = WorkerState::new();
    let mut sp = Some(sp0);
    let mut first = true;
    loop {
        let n = seat.world.len();
        let mut epoch_cfg = *cfg;
        epoch_cfg.n_ranks = n;
        let mut replica = sp.take().expect("the loop always refills the replica");
        if !first {
            // re-tile the partition layout over the epoch's world (and
            // drop any half-finished round the fault tore down)
            replica.reform(n)?;
        }
        first = false;
        if let Some(bytes) = seat.sp_import.take() {
            replica.import_state(&bytes)?;
        }
        if let Some(err) = seat.err_restore.take() {
            state.err = err;
        }
        state.start_t = state.start_t.max(seat.resume_t);

        let chaos = ecfg.chaos_kill_at.clone();
        let probe: Box<dyn FnMut(usize) -> Result<()> + '_> = Box::new(move |t| {
            if chaos.iter().any(|&(kt, kr)| kt == t && kr == orig_rank) {
                return Err(Error::ChaosKilled { rank: orig_rank, t });
            }
            home.probe(orig_rank, t)
        });
        let guard = AbortOnPanic(seat.transport.as_ref());
        let ep = Endpoint::new(seat.rank, seat.transport.as_ref());
        let mut worker = SimWorker::new(seat.rank, replica, gen, &epoch_cfg, ep)
            .with_epoch(seat.epoch)
            .with_data_rank(orig_rank)
            .with_probe(probe);
        let out = worker.run_state(&mut state);
        let replica = worker.into_sparsifier();
        drop(guard);
        match out {
            Ok(()) => return Ok(state.records),
            Err(e @ Error::ChaosKilled { .. }) => {
                home.on_chaos_kill(orig_rank, &state.err);
                return Err(e);
            }
            Err(e)
                if (e.is_membership_fault() || e.looks_like_peer_loss())
                    && seat.epoch < ecfg.max_epochs =>
            {
                let lost = match &e {
                    Error::PeerLost { rank, .. } => seat.world.get(*rank).copied(),
                    _ => None,
                };
                crate::log_info!(
                    "elastic",
                    "rank {orig_rank} (epoch {} seat {}) lost the cluster ({e}); \
                     re-forming at epoch {}",
                    seat.epoch,
                    seat.rank,
                    seat.epoch + 1
                );
                // always poison before leaving: the in-process waits
                // are untimed, and closed sockets fail peers over fast
                seat.transport.abort();
                let export = replica.export_state();
                sp = Some(replica);
                seat = home.reform(orig_rank, state.start_t, export, lost)?;
                crate::log_info!(
                    "elastic",
                    "rank {orig_rank} seated: epoch {} seat {} world {:?} resume_t {}",
                    seat.epoch,
                    seat.rank,
                    seat.world,
                    seat.resume_t
                );
            }
            Err(e) => {
                seat.transport.abort();
                return Err(e);
            }
        }
    }
}

/// Thread-per-rank elastic driver (the `sim --elastic` path): like
/// [`run_threaded`](crate::cluster::run_threaded) but every rank runs
/// the recovery loop over a shared [`ElasticCluster`], so an injected
/// death shrinks the cluster instead of failing the run. The trace is
/// the lowest-ranked survivor's records.
pub fn run_elastic_threaded(
    gen: &SynthGen,
    make_sparsifier: &SparsifierFactory,
    cfg: &SimCfg,
    flavor: ElasticFlavor,
    ecfg: &ElasticCfg,
) -> Result<Trace> {
    let n = cfg.n_ranks;
    if n == 0 {
        return Err(Error::invalid("n_ranks must be >= 1"));
    }
    if cfg.pipeline {
        return Err(Error::invalid(
            "elastic membership requires the sequential loop; drop --pipeline",
        ));
    }
    if ecfg.chaos_kill_at.len() > 1 {
        // the thread-per-rank engine joins every rank's recovery loop
        // at the end and selects the first surviving trace; a second
        // kill site would silently be honored by the probe but the
        // engine has no per-site assertions or rejoin choreography for
        // it — reject rather than half-run the schedule
        return Err(Error::config(format!(
            "the in-process elastic engine supports a single --chaos-kill-at \
             site; got a schedule of {} — use `launch` for multi-fault drills",
            ecfg.chaos_kill_at.len()
        )));
    }
    for &(_, victim) in &ecfg.chaos_kill_at {
        if victim >= n {
            return Err(Error::invalid(format!(
                "--chaos-kill-at names rank {victim}, but the world has {n} ranks"
            )));
        }
    }
    let cluster = ElasticCluster::new(n, flavor, ecfg.grace, Duration::from_secs(30))?;
    // replicas are built on the launcher thread (the factory need not
    // be Sync), then each is moved onto its rank's thread
    let mut replicas = Vec::with_capacity(n);
    for _ in 0..n {
        replicas.push(make_sparsifier(gen.n_g(), n)?);
    }
    let name = replicas[0].name();
    let results: Vec<Result<Vec<IterRecord>>> = std::thread::scope(|s| {
        let cluster = &cluster;
        let handles: Vec<_> = replicas
            .into_iter()
            .enumerate()
            .map(|(rank, sp)| {
                s.spawn(move || {
                    let seat = cluster.initial_seat(rank)?;
                    run_elastic_seat(gen, cfg, rank, sp, seat, cluster, ecfg)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| {
                h.join()
                    .unwrap_or_else(|_| Err(Error::invariant("elastic worker panicked")))
            })
            .collect()
    });
    let mut canonical: Option<Vec<IterRecord>> = None;
    for res in results {
        match res {
            Ok(records) => {
                if canonical.is_none() {
                    canonical = Some(records);
                }
            }
            // the injected death is the experiment, not a run failure
            Err(Error::ChaosKilled { .. }) => {}
            Err(e) => return Err(e),
        }
    }
    let records = canonical.ok_or_else(|| {
        Error::invariant("every rank was chaos-killed; no survivor produced a trace")
    })?;
    let mut trace = Trace::new(&name, &gen.model.name, n);
    trace.pipelined = false;
    for rec in records {
        trace.push(rec);
    }
    Ok(trace)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::engine::run_threaded;
    use crate::coordinator::{ExDyna, ExDynaCfg};
    use crate::grad::synth::{DecayCfg, SynthModel};

    fn sim_cfg(n: usize, iters: usize) -> SimCfg {
        SimCfg {
            n_ranks: n,
            iters,
            compute_s: 0.01,
            ..Default::default()
        }
    }

    fn gen(n: usize) -> SynthGen {
        let model = SynthModel::profile("t", 24_000, 4, 5, DecayCfg::default());
        SynthGen::new(model, n, 0.5, 17, false)
    }

    fn mk(n_g: usize, nr: usize) -> Result<Box<dyn Sparsifier>> {
        Ok(Box::new(ExDyna::new(n_g, nr, ExDynaCfg::default_for(nr))?))
    }

    fn ecfg(kill: &[(usize, usize)]) -> ElasticCfg {
        ElasticCfg {
            enabled: true,
            chaos_kill_at: kill.to_vec(),
            max_epochs: 8,
            grace: Duration::from_secs(5),
        }
    }

    #[test]
    fn kill_at_parses_and_rejects_garbage() {
        assert_eq!(parse_kill_at("5:2").unwrap(), vec![(5, 2)]);
        assert_eq!(parse_kill_at(" 10 : 0 ").unwrap(), vec![(10, 0)]);
        assert_eq!(parse_kill_at("4:0,8:1").unwrap(), vec![(4, 0), (8, 1)]);
        assert!(parse_kill_at("5").is_err());
        assert!(parse_kill_at("a:b").is_err());
        assert!(parse_kill_at("5:2:1").is_err());
        assert!(parse_kill_at("4:0,").is_err(), "trailing comma is garbage");
        assert!(
            parse_kill_at("4:1,8:1").is_err(),
            "a killed rank cannot die twice"
        );
    }

    #[test]
    fn succession_election_is_deterministic_and_total() {
        let world: Vec<u32> = vec![0, 1, 2, 3];
        let dead = BTreeSet::new();
        assert_eq!(elect_coordinator(&world, &dead), Some(0));
        let dead: BTreeSet<u32> = [0].into();
        assert_eq!(elect_coordinator(&world, &dead), Some(1));
        let dead: BTreeSet<u32> = [0, 1].into();
        assert_eq!(elect_coordinator(&world, &dead), Some(2));
        let dead: BTreeSet<u32> = [0, 1, 2, 3].into();
        assert_eq!(elect_coordinator(&world, &dead), None);
    }

    #[test]
    fn fault_free_elastic_matches_the_plain_threaded_trace() {
        let n = 3;
        let g = gen(n);
        let cfg = sim_cfg(n, 8);
        let plain = run_threaded(&g, &mk, &cfg).unwrap();
        let elastic =
            run_elastic_threaded(&g, &mk, &cfg, ElasticFlavor::Local, &ecfg(&[])).unwrap();
        assert_eq!(plain.records.len(), elastic.records.len());
        for (a, b) in plain.records.iter().zip(elastic.records.iter()) {
            assert_eq!(a.t, b.t);
            assert_eq!(a.k_actual, b.k_actual);
            assert_eq!(a.k_sum, b.k_sum);
            assert_eq!(a.delta.to_bits(), b.delta.to_bits());
            assert_eq!(a.global_err.to_bits(), b.global_err.to_bits());
            assert_eq!(b.epoch, 0, "fault-free run never leaves epoch 0");
        }
    }

    #[test]
    fn survivors_outlive_a_chaos_kill_on_the_local_flavor() {
        let n = 4;
        let iters = 12;
        let g = gen(n);
        let cfg = sim_cfg(n, iters);
        let trace =
            run_elastic_threaded(&g, &mk, &cfg, ElasticFlavor::Local, &ecfg(&[(5, 2)])).unwrap();
        // the transition may cost each survivor the record of the
        // iteration the fault interrupted
        assert!(
            trace.records.len() >= iters - 2,
            "expected >= {} records, got {}",
            iters - 2,
            trace.records.len()
        );
        assert_eq!(trace.records.last().unwrap().t, iters - 1);
        assert_eq!(trace.records.first().unwrap().epoch, 0);
        assert_eq!(
            trace.records.last().unwrap().epoch,
            1,
            "the tail must run in the re-formed epoch"
        );
        let flip = trace.records.iter().filter(|r| r.epoch == 1).count();
        assert!(flip > 0 && flip < trace.records.len());
    }

    #[test]
    fn survivors_outlive_a_chaos_kill_on_the_ring_flavor() {
        let n = 3;
        let iters = 10;
        let g = gen(n);
        let cfg = sim_cfg(n, iters);
        let trace =
            run_elastic_threaded(&g, &mk, &cfg, ElasticFlavor::Ring, &ecfg(&[(4, 1)])).unwrap();
        assert!(trace.records.len() >= iters - 2);
        assert_eq!(trace.records.last().unwrap().t, iters - 1);
        assert_eq!(trace.records.last().unwrap().epoch, 1);
    }

    /// The coordinator is a casualty like any other in the in-process
    /// twin: killing original rank 0 promotes rank 1 and the survivors
    /// finish the run at epoch 1.
    #[test]
    fn survivors_outlive_a_rank0_kill_on_the_local_flavor() {
        let n = 4;
        let iters = 12;
        let g = gen(n);
        let cfg = sim_cfg(n, iters);
        let trace =
            run_elastic_threaded(&g, &mk, &cfg, ElasticFlavor::Local, &ecfg(&[(5, 0)])).unwrap();
        assert!(trace.records.len() >= iters - 2);
        assert_eq!(trace.records.last().unwrap().t, iters - 1);
        assert_eq!(
            trace.records.last().unwrap().epoch,
            1,
            "survivors re-form after the coordinator's death"
        );
    }

    #[test]
    fn survivors_outlive_a_rank0_kill_on_the_ring_flavor() {
        let n = 3;
        let iters = 10;
        let g = gen(n);
        let cfg = sim_cfg(n, iters);
        let trace =
            run_elastic_threaded(&g, &mk, &cfg, ElasticFlavor::Ring, &ecfg(&[(4, 0)])).unwrap();
        assert!(trace.records.len() >= iters - 2);
        assert_eq!(trace.records.last().unwrap().t, iters - 1);
        assert_eq!(trace.records.last().unwrap().epoch, 1);
    }

    /// The in-process engine honors exactly one kill site; a longer
    /// schedule is a typed config error, not a silently dropped tail.
    #[test]
    fn a_multi_site_schedule_is_rejected_in_process() {
        let n = 4;
        let g = gen(n);
        let cfg = sim_cfg(n, 12);
        let err = run_elastic_threaded(
            &g,
            &mk,
            &cfg,
            ElasticFlavor::Local,
            &ecfg(&[(4, 0), (8, 1)]),
        )
        .unwrap_err();
        assert!(
            matches!(err, Error::Config(_)),
            "expected Error::Config, got {err:?}"
        );
    }

    #[test]
    fn a_killed_rank_rejoins_with_its_error_feedback_restored() {
        let n = 3;
        let iters = 60;
        let kill_t = 5;
        let g = gen(n);
        let cfg = sim_cfg(n, iters);
        let cluster = Arc::new(
            ElasticCluster::new(n, ElasticFlavor::Local, Duration::from_secs(5), {
                Duration::from_secs(30)
            })
            .unwrap(),
        );
        let e = ecfg(&[(kill_t, 1)]);
        let results: Vec<Result<Vec<IterRecord>>> = std::thread::scope(|s| {
            let mut handles = Vec::new();
            for rank in 0..n {
                let cluster = Arc::clone(&cluster);
                let sp = mk(g.n_g(), n).unwrap();
                let cfg = &cfg;
                let g = &g;
                let e = &e;
                handles.push(s.spawn(move || {
                    let seat = cluster.initial_seat(rank)?;
                    run_elastic_seat(g, cfg, rank, sp, seat, cluster.as_ref(), e)
                }));
            }
            // the victim's replacement: retry until the death lands,
            // then wait out the boundary
            let cluster2 = Arc::clone(&cluster);
            let cfg = &cfg;
            let g = &g;
            let e2 = ElasticCfg {
                chaos_kill_at: Vec::new(),
                ..e.clone()
            };
            handles.push(s.spawn(move || {
                let deadline = Instant::now() + Duration::from_secs(20);
                let seat = loop {
                    match cluster2.join(1) {
                        Ok(seat) => break seat,
                        Err(_) if Instant::now() < deadline => {
                            std::thread::sleep(Duration::from_micros(500))
                        }
                        Err(err) => return Err(err),
                    }
                };
                assert!(
                    seat.err_restore.is_some(),
                    "in-process rejoin must restore the banked EF accumulator"
                );
                assert!(seat.sp_import.is_some(), "joiner inherits the donor snapshot");
                // the registration usually lands after the shrink epoch
                // formed (epoch >= 2), but can ride the shrink boundary
                // itself (epoch 1) — both are correct seatings
                assert!(seat.epoch >= 1, "rejoin happens at an epoch boundary");
                run_elastic_seat(g, cfg, 1, mk(g.n_g(), seat.world.len()).unwrap(), seat,
                    cluster2.as_ref(), &e2)
            }));
            handles
                .into_iter()
                .map(|h| {
                    h.join()
                        .unwrap_or_else(|_| Err(Error::invariant("worker panicked")))
                })
                .collect()
        });
        // ranks 0 and 2 survive end to end; rank 1 dies; the rejoiner
        // finishes the tail of the run
        assert!(results[0].is_ok(), "rank 0: {:?}", results[0].as_ref().err());
        assert!(matches!(results[1], Err(Error::ChaosKilled { rank: 1, t }) if t == kill_t));
        assert!(results[2].is_ok(), "rank 2: {:?}", results[2].as_ref().err());
        let rejoined = results[3].as_ref().expect("rejoiner must finish");
        assert!(!rejoined.is_empty(), "rejoiner must complete iterations");
        assert_eq!(rejoined.last().unwrap().t, iters - 1);
        assert!(rejoined.first().unwrap().epoch >= 1);
        let survivor = results[0].as_ref().unwrap();
        assert_eq!(survivor.last().unwrap().t, iters - 1);
        // once the rejoiner is seated the world is back to 3 ranks and
        // every member sees the same final epoch
        assert_eq!(
            survivor.last().unwrap().epoch,
            rejoined.last().unwrap().epoch
        );
    }
}
