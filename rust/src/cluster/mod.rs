//! The cluster layer: rank workers, message transports (in-process and
//! socket), and the training engines that run over them.
//!
//! The paper's subject is *scalability* — selection/communication cost as
//! the worker count grows — so the trainer models a cluster, not a loop:
//!
//! * [`transport`] — the [`Transport`] abstraction collectives move
//!   messages over. Data movement is real but *zero-copy*: payloads are
//!   `Arc`-shared and an all-gather returns the whole rank-indexed board
//!   as one shared `Arc<[Message]>` slab, so fanning a round out to n
//!   ranks is O(n) refcount bumps rather than O(n²·k) element copies.
//!   Every all-gather also exists split-phase ([`PendingRound`]:
//!   nonblocking start with the contribution genuinely in flight,
//!   blocking generation-stamped finish, abort-aware and
//!   deadline-bounded) — the substrate of step-level pipelining
//!   (`pipeline = true`), where [`SimWorker`] overlaps iteration t+1's
//!   compute with iteration t's collective and the clock charges
//!   `max(compute, comm)` per pair.
//!   The α–β [`CostModel`] independently charges what the operation
//!   would cost on the modeled wire (padded payloads, every rank's
//!   contribution) — the modeled clock always bills the real byte
//!   volume, regardless of how cheaply the harness moved it. The
//!   modeled collectives are *ring* algorithms (`(n-1)·α +
//!   (n-1)/n·V·β` per all-gather), so traces are identical on every
//!   transport; what changes per transport is the harness's real
//!   traffic shape. Implementations:
//!   * [`LocalTransport`] — in-process rendezvous (mutex/condvar slot
//!     board) for one OS thread per rank; published board slabs are
//!     double-buffered and recycled, so steady-state rounds make zero
//!     heap allocations (pinned by `rust/tests/alloc_regression.rs`);
//!   * [`net::TcpTransport`] — hub-mediated TCP star for one *process*
//!     per rank (same host or across hosts), with a length-prefixed
//!     checksummed wire codec doing bulk little-endian slab conversion
//!     ([`net::codec`]), persistent per-connection encode/decode
//!     buffers, a rank-claim handshake ([`net::handshake`]),
//!     deadline-bounded IO and abort poisoning that closes sockets so
//!     peers error out instead of hanging. The hub's NIC carries
//!     `(n-1)` contributions in plus `(n-1)` whole boards out per
//!     round — fine on loopback, the build-up pathology on real NICs;
//!   * [`net::RingTransport`] — chunked TCP ring, one process per
//!     rank: every rank forwards `n-1` generation-stamped chunks to
//!     its right neighbor, so per-round traffic is identical on every
//!     link and matches the cost model's ring assumption
//!     ([`CostModel::allgather_star`] quantifies the star's modeled
//!     penalty). Rank 0 doubles as the bootstrap coordinator only;
//!   * [`RingLocal`] — the in-process twin of the ring (channels, no
//!     sockets), used by the conformance suite and `RealTrainer` to
//!     exercise ring semantics without socket overhead.
//!
//!   `rust/tests/transport_conformance.rs` runs one shared battery
//!   (board ordering, NaN bit-exactness, abort poisoning, trace
//!   parity, ...) over all four, so every future transport inherits
//!   the full matrix.
//! * [`worker`] — [`SimWorker`]: one rank's Alg. 1 loop (own sparsifier
//!   replica, own error/accumulator buffers, own reusable
//!   [`RoundScratch`]), shared-nothing except the transport. The same
//!   worker runs unchanged over either transport.
//!
//! [RoundScratch]: crate::collectives::RoundScratch
//! * [`engine`] — [`run_threaded`]: launch thread-per-rank workers over
//!   a [`LocalTransport`] and merge the records;
//!   [`run_rank_on_transport`]: run one rank of a multi-process cluster
//!   over any transport (the `exdyna launch` path).
//! * [`elastic`] — epoch-based elastic membership (`--elastic`): when a
//!   rank dies mid-round the survivors drain the poisoned transport,
//!   re-form a brand-new epoch-stamped transport over the remaining
//!   ranks, re-tile the selection partition, and resume from the last
//!   committed iteration — instead of the whole cluster aborting. A
//!   restarted rank rejoins at an epoch boundary with a state snapshot.
//!   `--chaos-kill-at ITER:RANK` injects a deterministic death for
//!   testing the recovery path end to end.
//!
//! [`EngineKind`] selects between the threaded engine and the legacy
//! lock-step path (kept for bit-exact comparison); [`TransportKind`]
//! selects the transport (`transport = "tcp" | "ring"` in TOML,
//! `--transport` on the CLI, or the `launch` subcommand); and
//! [`CollectiveKind`] selects the value-reduce collective
//! (`collective = "allgather" | "rsag"` in TOML, `--collective` on the
//! CLI): the default full-board all-gather, or the reduce-scatter →
//! all-gather ([`Transport::reduce_scatter_allgather`], wrapped
//! split-phase by [`PendingReduce`]) in which each rank reduces its
//! 1/n index shard in flight and only the n reduced shards are
//! all-gathered — per-rank received volume `2(n-1)/n·V` instead of
//! `(n-1)·V`, with the modeled clock unchanged (it always charged the
//! rsag-shaped `2(n-1)·α + 2(n-1)/n·V·β` form).
//! With `--sparse-shards` the rsag round sheds its dense padding too:
//! shards travel as `(index, value)` entry lists holding only live
//! selections ([`Transport::rsag_sparse`], split-phase via
//! [`PendingSparseReduce`], wire form [`net::codec::Frame::SparseShard`]),
//! an optional per-hop re-top-k (`--shard-k`) caps every hop and its
//! discards return to the contributing rank as an error-feedback
//! residual — so per-rank received volume drops from dense
//! `2(n-1)/n·V` toward the live-entry volume
//! ([`CostModel::rsag_sparse_recv_bytes_per_rank`]).
//! `rust/tests/engine_parity.rs` pins trace equality across every
//! execution mode, including real multi-process star and ring runs.
//!
//! [CostModel]: crate::collectives::CostModel

pub mod elastic;
pub mod engine;
pub mod net;
pub mod ring_local;
pub mod testing;
pub mod transport;
pub mod worker;

pub use elastic::{
    elect_coordinator, parse_kill_at, run_elastic_seat, run_elastic_threaded, ElasticCfg,
    ElasticCluster, ElasticFlavor, Membership, Seat, SocketMember,
};
pub use engine::{
    run_rank_on_transport, run_rank_on_transport_obs, run_threaded, run_threaded_obs,
    run_threaded_with_stats, run_threaded_with_stats_obs, ClusterStats,
};
pub use net::{NetCfg, RingTransport, TcpTransport};
pub use ring_local::RingLocal;
pub use transport::{
    Endpoint, FloatBufPool, LocalTransport, Message, PendingReduce, PendingRound,
    PendingSparseReduce, RoundToken, SparseBufPool, SparseRound, Transport,
};
pub use worker::{SimWorker, WorkerState};

use crate::error::{Error, Result};

/// Which trainer engine executes the ranks.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum EngineKind {
    /// One OS thread per rank over a [`Transport`] (the default).
    #[default]
    Threaded,
    /// Legacy single-thread lock-step execution (bit-exact reference).
    Lockstep,
}

impl EngineKind {
    /// Parse a CLI/config name.
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "threaded" => Ok(EngineKind::Threaded),
            "lockstep" => Ok(EngineKind::Lockstep),
            other => Err(Error::invalid(format!(
                "unknown engine '{other}' (have: threaded, lockstep)"
            ))),
        }
    }

    /// Canonical name (round-trips through [`EngineKind::parse`]).
    pub fn name(&self) -> &'static str {
        match self {
            EngineKind::Threaded => "threaded",
            EngineKind::Lockstep => "lockstep",
        }
    }
}

impl std::str::FromStr for EngineKind {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self> {
        EngineKind::parse(s)
    }
}

impl std::fmt::Display for EngineKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Which transport moves messages between ranks.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum TransportKind {
    /// In-process rendezvous, one OS thread per rank (the default).
    #[default]
    Local,
    /// TCP sockets, hub-star, one process per rank (`exdyna launch`).
    Tcp,
    /// TCP sockets, chunked ring, one process per rank (`exdyna launch
    /// --transport ring`): every link carries the same `n - 1` messages
    /// per round instead of the star concentrating 2(n-1) board volumes
    /// on the hub's NIC.
    Ring,
}

impl TransportKind {
    /// Parse a CLI/config name.
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "local" => Ok(TransportKind::Local),
            "tcp" => Ok(TransportKind::Tcp),
            "ring" => Ok(TransportKind::Ring),
            other => Err(Error::invalid(format!(
                "unknown transport '{other}' (have: local, tcp, ring)"
            ))),
        }
    }

    /// Canonical name (round-trips through [`TransportKind::parse`]).
    pub fn name(&self) -> &'static str {
        match self {
            TransportKind::Local => "local",
            TransportKind::Tcp => "tcp",
            TransportKind::Ring => "ring",
        }
    }

    /// Does this kind run one OS process per rank over sockets (i.e.
    /// `sim` must defer to `launch`)?
    pub fn is_multiprocess(&self) -> bool {
        !matches!(self, TransportKind::Local)
    }
}

impl std::str::FromStr for TransportKind {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self> {
        TransportKind::parse(s)
    }
}

impl std::fmt::Display for TransportKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Which collective form moves the value reduce.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum CollectiveKind {
    /// Full-board all-gather + local reduce (the default): every rank
    /// receives all n contributions — `(n-1)·V` received per rank.
    #[default]
    Allgather,
    /// Reduce-scatter → all-gather: each rank reduces its 1/n index
    /// shard in flight, then the n reduced shards are all-gathered —
    /// `2(n-1)/n·V` received per rank, flat in n. Modeled times are
    /// identical to the default (the clock always charged this shape);
    /// reduced *values* differ in low bits because the shard sums
    /// accumulate in ring order rather than rank order.
    Rsag,
}

impl CollectiveKind {
    /// Parse a CLI/config name.
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "allgather" => Ok(CollectiveKind::Allgather),
            "rsag" => Ok(CollectiveKind::Rsag),
            other => Err(Error::invalid(format!(
                "unknown collective '{other}' (have: allgather, rsag)"
            ))),
        }
    }

    /// Canonical name (round-trips through [`CollectiveKind::parse`]).
    pub fn name(&self) -> &'static str {
        match self {
            CollectiveKind::Allgather => "allgather",
            CollectiveKind::Rsag => "rsag",
        }
    }
}

impl std::str::FromStr for CollectiveKind {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self> {
        CollectiveKind::parse(s)
    }
}

impl std::fmt::Display for CollectiveKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_kind_roundtrips() {
        for k in [EngineKind::Threaded, EngineKind::Lockstep] {
            assert_eq!(EngineKind::parse(k.name()).unwrap(), k);
            assert_eq!(k.name().parse::<EngineKind>().unwrap(), k);
        }
        assert!(EngineKind::parse("gpu").is_err());
        assert_eq!(EngineKind::default(), EngineKind::Threaded);
    }

    #[test]
    fn transport_kind_roundtrips() {
        for k in [TransportKind::Local, TransportKind::Tcp, TransportKind::Ring] {
            assert_eq!(TransportKind::parse(k.name()).unwrap(), k);
            assert_eq!(k.name().parse::<TransportKind>().unwrap(), k);
        }
        assert!(TransportKind::parse("carrier-pigeon").is_err());
        assert_eq!(TransportKind::default(), TransportKind::Local);
        assert!(!TransportKind::Local.is_multiprocess());
        assert!(TransportKind::Tcp.is_multiprocess());
        assert!(TransportKind::Ring.is_multiprocess());
    }

    #[test]
    fn collective_kind_roundtrips() {
        for k in [CollectiveKind::Allgather, CollectiveKind::Rsag] {
            assert_eq!(CollectiveKind::parse(k.name()).unwrap(), k);
            assert_eq!(k.name().parse::<CollectiveKind>().unwrap(), k);
        }
        assert!(CollectiveKind::parse("gossip").is_err());
        assert_eq!(CollectiveKind::default(), CollectiveKind::Allgather);
    }
}
