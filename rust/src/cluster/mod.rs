//! The cluster layer: rank workers, message transport, and the threaded
//! training engine.
//!
//! The paper's subject is *scalability* — selection/communication cost as
//! the worker count grows — so the trainer models a cluster, not a loop:
//!
//! * [`transport`] — the [`Transport`] abstraction collectives move
//!   messages over, and [`LocalTransport`], the in-process
//!   channels/barrier implementation (one OS thread per rank). Data
//!   movement is real; the α–β [`CostModel`] charges what the operation
//!   would cost on the modeled wire.
//! * [`worker`] — [`SimWorker`]: one rank's Alg. 1 loop (own sparsifier
//!   replica, own error/accumulator buffers), shared-nothing except the
//!   transport.
//! * [`engine`] — [`run_threaded`]: launch workers, merge per-rank
//!   records into one trace.
//!
//! [`EngineKind`] selects between this engine and the legacy lock-step
//! path (kept for bit-exact comparison; see
//! `rust/tests/engine_parity.rs`). The choice threads through `SimCfg`,
//! the TOML config, and the CLI (`--engine threaded|lockstep`).
//!
//! [CostModel]: crate::collectives::CostModel

pub mod engine;
pub mod transport;
pub mod worker;

pub use engine::{run_threaded, run_threaded_with_stats, ClusterStats};
pub use transport::{Endpoint, LocalTransport, Message, Transport};
pub use worker::SimWorker;

use crate::error::{Error, Result};

/// Which trainer engine executes the ranks.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum EngineKind {
    /// One OS thread per rank over a [`Transport`] (the default).
    #[default]
    Threaded,
    /// Legacy single-thread lock-step execution (bit-exact reference).
    Lockstep,
}

impl EngineKind {
    /// Parse a CLI/config name.
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "threaded" => Ok(EngineKind::Threaded),
            "lockstep" => Ok(EngineKind::Lockstep),
            other => Err(Error::invalid(format!(
                "unknown engine '{other}' (have: threaded, lockstep)"
            ))),
        }
    }

    /// Canonical name (round-trips through [`EngineKind::parse`]).
    pub fn name(&self) -> &'static str {
        match self {
            EngineKind::Threaded => "threaded",
            EngineKind::Lockstep => "lockstep",
        }
    }
}

impl std::str::FromStr for EngineKind {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self> {
        EngineKind::parse(s)
    }
}

impl std::fmt::Display for EngineKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_kind_roundtrips() {
        for k in [EngineKind::Threaded, EngineKind::Lockstep] {
            assert_eq!(EngineKind::parse(k.name()).unwrap(), k);
            assert_eq!(k.name().parse::<EngineKind>().unwrap(), k);
        }
        assert!(EngineKind::parse("gpu").is_err());
        assert_eq!(EngineKind::default(), EngineKind::Threaded);
    }
}
