//! [`RingLocal`] — the in-process twin of the TCP
//! [`RingTransport`](crate::cluster::net::RingTransport).
//!
//! Same algorithm, no sockets: one unbounded channel per directed ring
//! link (rank `r` → rank `(r + 1) % n`), one OS thread per rank. An
//! all-gather runs the identical `n - 1` forwarding steps as the wire
//! version — each rank pushes board slot `(rank - s) mod n` to its right
//! neighbor and pops slot `(rank - s - 1) mod n` from its left — with
//! every hop generation-stamped so cross-round mixing is a typed error,
//! not silent corruption. Because channel sends never block, the wire
//! transport's receive-before-send ordering trick is unnecessary here.
//!
//! Payloads stay `Arc`-shared end to end (a hop moves a refcount, never
//! elements) and each rank recycles its published board slab once the
//! caller drops it, so the only steady-state allocations are the
//! channel's per-hop nodes — this is the transport the conformance
//! suite and `RealTrainer` use to exercise ring *semantics* without
//! socket overhead. Failure semantics match the wire version: every
//! receive is deadline-bounded ([`RingLocal::with_timeout`]) and
//! [`Transport::abort`] poisons the transport, waking every blocked
//! receiver with an error — a broken ring never hangs.
//!
//! The reduce-scatter → all-gather collective runs the true chunked
//! ring schedule: phase 1 forwards each index chunk around the ring,
//! every rank adding its own contribution in place as the partial
//! passes through ([`Hop::Chunk`] buffers are *moved* down the
//! channels, mutated, and re-sent — never copied), so after `n - 1`
//! hops rank r holds its own fully reduced shard summed in the
//! canonical ring order; phase 2 all-gathers the n reduced shards with
//! `n - 1` more hops. Chunk buffers ride a per-rank free list (one
//! leaves at begin, one is absorbed at the end of the gather phase), so
//! steady-state reduce rounds allocate nothing beyond the channel's hop
//! nodes.
//!
//! The truly sparse rsag (`--sparse-shards`) runs the same two-phase
//! schedule with [`Hop::SparseChunk`] hops carrying `(position, value)`
//! entry lists instead of dense slices: the injector re-top-k's its own
//! slice before the step-0 send, every rank merge-adds its entries as
//! the partial passes through and re-applies the cap — keeping its own
//! discards as the residual the worker feeds back into error feedback —
//! and phase 2 forwards the reduced entry lists. The merge/cap schedule
//! is exactly [`reduce_sparse_shard_with`]'s canonical order, so
//! reduced entries and residuals are bit-identical to the board replay
//! and the wire ring, while each hop moves `entries · 8 B` instead of
//! `chunk_len · 4 B`.
//!
//! [`reduce_sparse_shard_with`]: crate::collectives::reduce_sparse_shard_with

use crate::cluster::transport::{
    poison_error, FloatBufPool, Message, RoundToken, SparseRound, Transport,
};
use crate::collectives::allreduce::shard_bounds;
use crate::collectives::sparse::{
    canonicalize_residual, merge_add_sparse, reduce_sparse_contributions_with, retain_top_k,
    SparseReduceScratch, SparseVec,
};
use crate::collectives::CostModel;
use crate::error::{Error, Result};
use crate::obs::ObsCounters;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// One hop on a ring link.
enum Hop {
    /// A forwarded board slot, stamped with the sender's round.
    Data {
        generation: u64,
        msg: Message,
    },
    /// One reduce-scatter hop: a chunk's partial (or reduced) values,
    /// stamped with the sender's round and position in the 2(n-1)-step
    /// schedule. The buffer is moved, mutated in place by the receiver,
    /// and forwarded — never copied.
    Chunk {
        generation: u64,
        step: usize,
        chunk: usize,
        vals: Vec<f32>,
    },
    /// One truly sparse rsag hop: a chunk's partial (or reduced)
    /// `(position, value)` entries, stamped like [`Hop::Chunk`].
    /// Positions are global union offsets (there is no wire to re-base
    /// for); the buffer is moved, merged into by the receiver, and
    /// forwarded — never copied.
    SparseChunk {
        generation: u64,
        step: usize,
        chunk: usize,
        sv: SparseVec,
    },
    /// Poison notice: the transport was aborted, by the named rank when
    /// the aborter identified itself ([`Transport::abort_from`]).
    Abort { by: Option<usize> },
}

/// One rank's ring endpoint state (each rank's calls come from its own
/// worker thread; the mutex makes the shared handle `Sync`).
struct RingRank {
    /// Send side of the link to rank `(rank + 1) % n`.
    tx_right: Sender<Hop>,
    /// Receive side of the link from rank `(rank + n - 1) % n`.
    rx_left: Receiver<Hop>,
    generation: u64,
    /// Rank-indexed slot board, retained across rounds.
    slots: Vec<Option<Message>>,
    /// Last round's published slab, kept for recycling.
    last: Option<Arc<[Message]>>,
    /// Free list of reduce-scatter chunk buffers: one is popped per
    /// reduce round at begin (the injected chunk) and one absorbed at
    /// the end of the gather phase, so the steady state recirculates a
    /// fixed set of buffers.
    chunk_free: Vec<Vec<f32>>,
    /// Free list of sparse chunk buffers — the [`Hop::SparseChunk`]
    /// twin of `chunk_free`.
    sparse_free: Vec<SparseVec>,
    /// Discards from the begin-time injector cap of a sparse reduce,
    /// carried to complete-time where the caller's residual buffer
    /// becomes available. One outstanding round per rank, so one stash.
    residual_stash: SparseVec,
    /// Permutation scratch for the begin-time re-top-k.
    perm: Vec<u32>,
    /// Per-chunk reduced-entry staging for a sparse reduce's gather
    /// phase (chunks arrive in ring order, `out` must end in position
    /// order). Grown to n lazily, cleared every round.
    shard_parts: Vec<SparseVec>,
    /// `true` between a split-phase begin and its complete/abandon —
    /// rejects double-starts (one outstanding round per rank).
    pending: bool,
}

/// In-process chunked-ring transport for one OS thread per rank.
pub struct RingLocal {
    n: usize,
    epoch: u64,
    timeout: Duration,
    poisoned: AtomicBool,
    /// The rank whose failure poisoned the ring, when the aborter
    /// identified itself; first attribution wins.
    poisoned_by: Mutex<Option<usize>>,
    /// Guards the per-rank abort-counter bump so repeated aborts (the
    /// elastic teardown path aborts defensively) count once.
    abort_counted: AtomicBool,
    ranks: Vec<Mutex<RingRank>>,
    /// Clones of every link's sender, used by [`Transport::abort`] to
    /// wake blocked receivers (kept apart from the per-rank state so
    /// abort never contends with a blocked round's lock).
    abort_tx: Mutex<Vec<Sender<Hop>>>,
    /// Per-rank wire counters (payload account only — hops move Arcs /
    /// buffers, not socket bytes, so the wire-byte account stays zero).
    /// Lock-free, kept outside the per-rank mutex.
    obs: Vec<ObsCounters>,
}

impl RingLocal {
    /// Ring for `n` ranks with the default 30 s per-round receive
    /// deadline.
    pub fn new(n: usize) -> Self {
        Self::with_timeout(n, Duration::from_secs(30))
    }

    /// Ring for `n` ranks; a rank whose left neighbor stays silent for
    /// `timeout` within one round surfaces [`Error::Net`] instead of
    /// blocking forever.
    pub fn with_timeout(n: usize, timeout: Duration) -> Self {
        Self::with_timeout_at_epoch(n, timeout, 0)
    }

    /// Ring for `n` ranks formed at membership epoch `epoch` — the
    /// elastic recovery path builds one of these per re-formation.
    pub fn with_timeout_at_epoch(n: usize, timeout: Duration, epoch: u64) -> Self {
        // link r carries hops from rank r to rank (r + 1) % n
        let mut txs = Vec::with_capacity(n);
        let mut rxs: Vec<Option<Receiver<Hop>>> = Vec::with_capacity(n);
        for _ in 0..n {
            let (tx, rx) = channel();
            txs.push(tx);
            rxs.push(Some(rx));
        }
        let ranks = (0..n)
            .map(|r| {
                Mutex::new(RingRank {
                    tx_right: txs[r].clone(),
                    // rank r's left link is the channel OUT of (r - 1) mod n
                    rx_left: rxs[(r + n - 1) % n]
                        .take()
                        .expect("each link's receiver is claimed exactly once"),
                    generation: 0,
                    slots: (0..n).map(|_| None).collect(),
                    last: None,
                    chunk_free: Vec::new(),
                    sparse_free: Vec::new(),
                    residual_stash: SparseVec::new(),
                    perm: Vec::new(),
                    shard_parts: Vec::new(),
                    pending: false,
                })
            })
            .collect();
        RingLocal {
            n,
            epoch,
            timeout,
            poisoned: AtomicBool::new(false),
            poisoned_by: Mutex::new(None),
            abort_counted: AtomicBool::new(false),
            ranks,
            abort_tx: Mutex::new(txs),
            obs: (0..n).map(|_| ObsCounters::new()).collect(),
        }
    }

    /// Typed fault for an observed poisoning: [`Error::PeerLost`] when
    /// the aborter identified itself, [`Error::Poisoned`] otherwise,
    /// stamped with the round this rank observed the poisoning at.
    fn poison_fault(&self, generation: u64) -> Error {
        poison_error(*self.poisoned_by.lock().unwrap(), generation)
    }

    fn poison(&self, by: Option<usize>) {
        self.poisoned.store(true, Ordering::SeqCst);
        // first attribution wins; the hops carry the winning one so
        // every receiver reports the same culprit
        let by = {
            let mut p = self.poisoned_by.lock().unwrap();
            if p.is_none() {
                *p = by;
            }
            *p
        };
        // wake every blocked receiver; sends to healthy links just queue
        // behind in-flight data and are consumed as the poison notice
        for tx in self.abort_tx.lock().unwrap().iter() {
            let _ = tx.send(Hop::Abort { by });
        }
        // every rank observes the poisoning at its next hop; the counter
        // describes the one poisoning, however many defensive abort
        // calls repeat it
        if !self.abort_counted.swap(true, Ordering::Relaxed) {
            for c in &self.obs {
                c.abort();
            }
        }
    }

    fn recv_hop(
        &self,
        rank: usize,
        rk: &mut RingRank,
        deadline: Instant,
        step: usize,
    ) -> Result<Hop> {
        let remaining = deadline.saturating_duration_since(Instant::now());
        match rk.rx_left.recv_timeout(remaining) {
            Ok(hop) => Ok(hop),
            Err(RecvTimeoutError::Timeout) => {
                self.obs[rank].deadline_wait();
                Err(Error::net(format!(
                    "ring step {step}: left neighbor stayed silent past the {:?} deadline",
                    self.timeout
                )))
            }
            Err(RecvTimeoutError::Disconnected) => {
                Err(Error::invariant("ring link disconnected — transport dropped"))
            }
        }
    }

    /// Receive one reduce-scatter hop and validate its full schedule
    /// stamp (round, step, chunk id, length) — any divergence is a
    /// typed error, never a silent mix of chunks.
    #[allow(clippy::too_many_arguments)]
    fn recv_chunk(
        &self,
        rank: usize,
        rk: &mut RingRank,
        deadline: Instant,
        want_gen: u64,
        want_step: usize,
        want_chunk: usize,
        want_len: usize,
    ) -> Result<Vec<f32>> {
        match self.recv_hop(rank, rk, deadline, want_step)? {
            Hop::Chunk {
                generation,
                step,
                chunk,
                vals,
            } => {
                if generation != want_gen {
                    return Err(Error::protocol(format!(
                        "generation mismatch from left neighbor: got {generation}, \
                         expected {want_gen} — workers diverged"
                    )));
                }
                if step != want_step || chunk != want_chunk {
                    return Err(Error::protocol(format!(
                        "reduce-scatter schedule divergence: got chunk {chunk} at \
                         step {step}, expected chunk {want_chunk} at step {want_step}"
                    )));
                }
                if vals.len() != want_len {
                    return Err(Error::protocol(format!(
                        "chunk {chunk} carries {} values, expected {want_len} — \
                         contribution lengths diverged",
                        vals.len()
                    )));
                }
                self.obs[rank].payload_rx(vals.len() * CostModel::DENSE_ENTRY_BYTES);
                Ok(vals)
            }
            Hop::Data { .. } => Err(Error::protocol(
                "expected a reduce-scatter chunk from the left neighbor, got a \
                 board hop — workers diverged",
            )),
            Hop::SparseChunk { .. } => Err(Error::protocol(
                "expected a dense reduce-scatter chunk from the left neighbor, \
                 got a sparse one — workers diverged on --sparse-shards",
            )),
            Hop::Abort { by } => Err(poison_error(by, want_gen)),
        }
    }

    /// Receive one sparse rsag hop and validate its full schedule stamp
    /// plus the entries' shard bounds `[cs, ce)` — any divergence is a
    /// typed error, never a silent mix of chunks.
    #[allow(clippy::too_many_arguments)]
    fn recv_sparse_chunk(
        &self,
        rank: usize,
        rk: &mut RingRank,
        deadline: Instant,
        want_gen: u64,
        want_step: usize,
        want_chunk: usize,
        bounds: (usize, usize),
    ) -> Result<SparseVec> {
        match self.recv_hop(rank, rk, deadline, want_step)? {
            Hop::SparseChunk {
                generation,
                step,
                chunk,
                sv,
            } => {
                if generation != want_gen {
                    return Err(Error::protocol(format!(
                        "generation mismatch from left neighbor: got {generation}, \
                         expected {want_gen} — workers diverged"
                    )));
                }
                if step != want_step || chunk != want_chunk {
                    return Err(Error::protocol(format!(
                        "sparse rsag schedule divergence: got chunk {chunk} at \
                         step {step}, expected chunk {want_chunk} at step {want_step}"
                    )));
                }
                let (cs, ce) = bounds;
                let in_bounds = match (sv.idx.first(), sv.idx.last()) {
                    (Some(&first), Some(&last)) => {
                        first as usize >= cs && (last as usize) < ce
                    }
                    _ => true, // an empty chunk is always in bounds
                };
                if !in_bounds {
                    return Err(Error::protocol(format!(
                        "sparse chunk {chunk} carries positions outside its shard \
                         [{cs}, {ce}) — union layouts diverged"
                    )));
                }
                self.obs[rank].payload_rx(sv.payload_bytes());
                Ok(sv)
            }
            Hop::Chunk { .. } => Err(Error::protocol(
                "expected a sparse rsag chunk from the left neighbor, got a \
                 dense one — workers diverged on --sparse-shards",
            )),
            Hop::Data { .. } => Err(Error::protocol(
                "expected a sparse rsag chunk from the left neighbor, got a \
                 board hop — workers diverged",
            )),
            Hop::Abort { by } => Err(poison_error(by, want_gen)),
        }
    }
}

impl Transport for RingLocal {
    fn n_ranks(&self) -> usize {
        self.n
    }

    fn allgather(&self, rank: usize, msg: Message) -> Result<Arc<[Message]>> {
        // the blocking round is the split phases back to back
        let token = self.allgather_begin(rank, msg)?;
        self.allgather_complete(rank, token)
    }

    fn allgather_begin(&self, rank: usize, msg: Message) -> Result<RoundToken> {
        if rank >= self.n {
            return Err(Error::invalid(format!(
                "rank {rank} out of range (n = {})",
                self.n
            )));
        }
        let mut rk = self.ranks[rank].lock().unwrap();
        if self.poisoned.load(Ordering::SeqCst) {
            return Err(self.poison_fault(rk.generation));
        }
        if rk.pending {
            return Err(Error::invariant(format!(
                "rank {rank} double-started a split-phase ring round (round {} \
                 is still in flight — finish or drop it first)",
                rk.generation
            )));
        }
        let my_gen = rk.generation;
        rk.slots[rank] = Some(msg);
        if self.n > 1 {
            // the step-0 chunk goes out eagerly (channel sends never
            // block), so the contribution is genuinely in flight while
            // the caller computes between begin and complete
            let fwd = rk.slots[rank]
                .as_ref()
                .expect("deposited just above")
                .clone();
            let bytes = fwd.payload_bytes();
            rk.tx_right
                .send(Hop::Data {
                    generation: my_gen,
                    msg: fwd,
                })
                .map_err(|_| Error::invariant("ring link disconnected — transport dropped"))?;
            self.obs[rank].payload_tx(bytes);
        }
        rk.pending = true;
        self.obs[rank].round(crate::cluster::CollectiveKind::Allgather);
        Ok(RoundToken::deferred(my_gen))
    }

    fn allgather_complete(&self, rank: usize, token: RoundToken) -> Result<Arc<[Message]>> {
        if rank >= self.n {
            return Err(Error::invalid(format!(
                "rank {rank} out of range (n = {})",
                self.n
            )));
        }
        let mut rk = self.ranks[rank].lock().unwrap();
        if !rk.pending {
            return Err(Error::invariant(format!(
                "rank {rank} completing a ring round it never started"
            )));
        }
        // cleared up front: an erroring round poisons the transport (the
        // worker contract), so there is nothing left to hand back anyway
        rk.pending = false;
        let my_gen = rk.generation;
        if token.generation() != my_gen {
            return Err(Error::invariant(format!(
                "rank {rank} completing round {}, but the ring is at round {my_gen}",
                token.generation()
            )));
        }
        if self.poisoned.load(Ordering::SeqCst) {
            return Err(self.poison_fault(my_gen));
        }
        let n = self.n;
        let deadline = Instant::now() + self.timeout;
        for step in 0..n - 1 {
            let send_idx = (rank + n - step) % n;
            let recv_idx = (send_idx + n - 1) % n;
            if step > 0 {
                // step 0's send already happened in begin; later steps
                // forward the chunk received in the previous step
                let fwd = rk.slots[send_idx]
                    .as_ref()
                    .expect("forwarding order fills the slot before it is sent")
                    .clone();
                let bytes = fwd.payload_bytes();
                rk.tx_right
                    .send(Hop::Data {
                        generation: my_gen,
                        msg: fwd,
                    })
                    .map_err(|_| {
                        Error::invariant("ring link disconnected — transport dropped")
                    })?;
                self.obs[rank].payload_tx(bytes);
            }
            match self.recv_hop(rank, &mut rk, deadline, step)? {
                Hop::Data { generation, msg } if generation == my_gen => {
                    self.obs[rank].payload_rx(msg.payload_bytes());
                    rk.slots[recv_idx] = Some(msg);
                }
                Hop::Data { generation, .. } => {
                    return Err(Error::protocol(format!(
                        "generation mismatch from left neighbor: got {generation}, \
                         expected {my_gen} — workers diverged"
                    )))
                }
                Hop::Chunk { .. } | Hop::SparseChunk { .. } => {
                    return Err(Error::protocol(
                        "expected a board hop from the left neighbor, got a \
                         reduce-scatter chunk — workers diverged",
                    ))
                }
                Hop::Abort { by } => return Err(poison_error(by, my_gen)),
            }
        }
        let rk = &mut *rk;
        let board = crate::cluster::transport::publish_recycled(&mut rk.slots, &mut rk.last);
        rk.generation = my_gen.wrapping_add(1);
        Ok(board)
    }

    fn allgather_abandon(&self, rank: usize, token: RoundToken) {
        // peers need this rank's n-1 forwarding hops to complete the
        // round: run it to completion and discard the board; if the ring
        // is broken mid-forward, poison it so nobody waits out a silence
        if self.allgather_complete(rank, token).is_err() {
            self.abort();
        }
    }

    fn rsag_begin(&self, rank: usize, contribution: Arc<Vec<f32>>) -> Result<RoundToken> {
        if rank >= self.n {
            return Err(Error::invalid(format!(
                "rank {rank} out of range (n = {})",
                self.n
            )));
        }
        let mut rk = self.ranks[rank].lock().unwrap();
        if self.poisoned.load(Ordering::SeqCst) {
            return Err(self.poison_fault(rk.generation));
        }
        if rk.pending {
            return Err(Error::invariant(format!(
                "rank {rank} double-started a split-phase ring round (round {} \
                 is still in flight — finish or drop it first)",
                rk.generation
            )));
        }
        let my_gen = rk.generation;
        if self.n > 1 {
            // the step-0 partial is this rank's own slice of chunk
            // (rank - 1) mod n, injected eagerly so the reduce is in
            // flight while the caller computes between begin and
            // complete; the buffer leaves the free list here and its
            // twin is absorbed back at the end of the gather phase
            let n = self.n;
            let chunk = (rank + n - 1) % n;
            let (cs, ce) = shard_bounds(contribution.len(), n, chunk);
            let mut vals = rk.chunk_free.pop().unwrap_or_default();
            vals.clear();
            vals.extend_from_slice(&contribution[cs..ce]);
            let bytes = vals.len() * CostModel::DENSE_ENTRY_BYTES;
            rk.tx_right
                .send(Hop::Chunk {
                    generation: my_gen,
                    step: 0,
                    chunk,
                    vals,
                })
                .map_err(|_| Error::invariant("ring link disconnected — transport dropped"))?;
            self.obs[rank].payload_tx(bytes);
        }
        rk.pending = true;
        self.obs[rank].round(crate::cluster::CollectiveKind::Rsag);
        // the contribution rides the token: complete adds it in place to
        // every partial that passes through this rank
        Ok(RoundToken::deferred_with_stash(
            my_gen,
            Message::Floats(contribution),
        ))
    }

    fn rsag_complete(
        &self,
        rank: usize,
        mut token: RoundToken,
        shards: &mut FloatBufPool,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        // chunk buffers ride the per-rank free list, not the shard pool
        let _ = shards;
        if rank >= self.n {
            return Err(Error::invalid(format!(
                "rank {rank} out of range (n = {})",
                self.n
            )));
        }
        let mut rk = self.ranks[rank].lock().unwrap();
        if !rk.pending {
            return Err(Error::invariant(format!(
                "rank {rank} completing a ring round it never started"
            )));
        }
        rk.pending = false;
        let my_gen = rk.generation;
        if token.generation() != my_gen {
            return Err(Error::invariant(format!(
                "rank {rank} completing round {}, but the ring is at round {my_gen}",
                token.generation()
            )));
        }
        if self.poisoned.load(Ordering::SeqCst) {
            return Err(self.poison_fault(my_gen));
        }
        let contribution = match token.take_stash() {
            Some(Message::Floats(v)) => v,
            _ => {
                return Err(Error::invariant(
                    "ring reduce token lost its stashed contribution",
                ))
            }
        };
        let n = self.n;
        let len = contribution.len();
        out.clear();
        out.resize(len, 0.0);
        if n == 1 {
            out.copy_from_slice(&contribution);
            rk.generation = my_gen.wrapping_add(1);
            return Ok(());
        }
        let deadline = Instant::now() + self.timeout;
        // phase 1 — reduce-scatter: at step s forward the partial
        // accumulated at step s - 1 (step 0's injection went out in
        // begin), then receive chunk (rank - 2 - s) mod n and add the
        // own contribution in place; after n - 1 steps `carry` is this
        // rank's fully reduced shard, summed in the canonical ring
        // order (injector rank + 1 first, owner last)
        let mut carry: Vec<f32> = Vec::new();
        for step in 0..n - 1 {
            if step > 0 {
                let chunk = (rank + 2 * n - 1 - step) % n;
                let vals = std::mem::take(&mut carry);
                let bytes = vals.len() * CostModel::DENSE_ENTRY_BYTES;
                rk.tx_right
                    .send(Hop::Chunk {
                        generation: my_gen,
                        step,
                        chunk,
                        vals,
                    })
                    .map_err(|_| {
                        Error::invariant("ring link disconnected — transport dropped")
                    })?;
                self.obs[rank].payload_tx(bytes);
            }
            let chunk = (rank + 2 * n - 2 - step) % n;
            let (cs, ce) = shard_bounds(len, n, chunk);
            let mut vals =
                self.recv_chunk(rank, &mut rk, deadline, my_gen, step, chunk, ce - cs)?;
            for (v, &x) in vals.iter_mut().zip(contribution[cs..ce].iter()) {
                *v += x;
            }
            carry = vals;
        }
        // phase 2 — all-gather of the n reduced shards: land the own
        // shard, then forward reduced chunks around the ring for n - 1
        // more hops, copying each received shard into `out`
        let (os, oe) = shard_bounds(len, n, rank);
        out[os..oe].copy_from_slice(&carry);
        for t in 0..n - 1 {
            let send_chunk = (rank + n - t) % n;
            let vals = std::mem::take(&mut carry);
            let bytes = vals.len() * CostModel::DENSE_ENTRY_BYTES;
            rk.tx_right
                .send(Hop::Chunk {
                    generation: my_gen,
                    step: n - 1 + t,
                    chunk: send_chunk,
                    vals,
                })
                .map_err(|_| Error::invariant("ring link disconnected — transport dropped"))?;
            self.obs[rank].payload_tx(bytes);
            let chunk = (rank + 2 * n - 1 - t) % n;
            let (cs, ce) = shard_bounds(len, n, chunk);
            let vals =
                self.recv_chunk(rank, &mut rk, deadline, my_gen, n - 1 + t, chunk, ce - cs)?;
            out[cs..ce].copy_from_slice(&vals);
            carry = vals;
        }
        // absorb the final buffer back into the free list — the twin of
        // the pop in begin, so steady-state rounds recirculate buffers
        let spare = std::mem::take(&mut carry);
        rk.chunk_free.push(spare);
        rk.generation = my_gen.wrapping_add(1);
        Ok(())
    }

    fn rsag_abandon(&self, rank: usize, token: RoundToken) {
        // peers mid-reduce depend on this rank's 2(n-1) hops: run the
        // round to completion and discard the result; poison the ring
        // if it is already broken so nobody waits out a silence
        let mut shards = FloatBufPool::new();
        let mut out = Vec::new();
        if self.rsag_complete(rank, token, &mut shards, &mut out).is_err() {
            self.abort();
        }
    }

    fn rsag_sparse_begin(
        &self,
        rank: usize,
        contribution: Arc<SparseVec>,
        round: SparseRound,
    ) -> Result<RoundToken> {
        if rank >= self.n {
            return Err(Error::invalid(format!(
                "rank {rank} out of range (n = {})",
                self.n
            )));
        }
        if self.poisoned.load(Ordering::SeqCst) {
            let rk = self.ranks[rank].lock().unwrap();
            return Err(self.poison_fault(rk.generation));
        }
        if let Some(&last) = contribution.idx.last() {
            if last as usize >= round.union_len {
                return Err(Error::invariant(format!(
                    "sparse contribution indexes position {last}, union length \
                     is {} — workers diverged",
                    round.union_len
                )));
            }
        }
        let mut rk = self.ranks[rank].lock().unwrap();
        if rk.pending {
            return Err(Error::invariant(format!(
                "rank {rank} double-started a split-phase ring round (round {} \
                 is still in flight — finish or drop it first)",
                rk.generation
            )));
        }
        let my_gen = rk.generation;
        if self.n > 1 {
            // the step-0 injection is this rank's own slice of chunk
            // (rank - 1) mod n. The injector's copy is the first merge
            // of the canonical schedule (merge into an empty partial),
            // so the per-hop cap applies HERE too — its discards are
            // this rank's residual, stashed until complete-time when
            // the caller's residual buffer is in hand.
            let n = self.n;
            let chunk = (rank + n - 1) % n;
            let (cs, ce) = shard_bounds(round.union_len, n, chunk);
            let (ci, cv) = contribution.range(cs, ce);
            let mut sv = rk.sparse_free.pop().unwrap_or_default();
            sv.copy_from(ci, cv);
            if round.shard_k > 0 && sv.len() > round.shard_k {
                let rk = &mut *rk;
                let (perm, stash) = (&mut rk.perm, &mut rk.residual_stash);
                retain_top_k(&mut sv, round.shard_k, perm, |i, v| stash.push_entry(i, v));
            }
            let bytes = sv.payload_bytes();
            rk.tx_right
                .send(Hop::SparseChunk {
                    generation: my_gen,
                    step: 0,
                    chunk,
                    sv,
                })
                .map_err(|_| Error::invariant("ring link disconnected — transport dropped"))?;
            self.obs[rank].payload_tx(bytes);
        }
        rk.pending = true;
        self.obs[rank].round(crate::cluster::CollectiveKind::Rsag);
        // the contribution rides the token: complete merges its
        // per-chunk slices into every partial that passes through
        Ok(RoundToken::deferred_with_stash(
            my_gen,
            Message::Sparse(contribution),
        ))
    }

    fn rsag_sparse_complete(
        &self,
        rank: usize,
        mut token: RoundToken,
        round: SparseRound,
        scratch: &mut SparseReduceScratch,
        out: &mut SparseVec,
        residual: &mut SparseVec,
    ) -> Result<()> {
        if rank >= self.n {
            return Err(Error::invalid(format!(
                "rank {rank} out of range (n = {})",
                self.n
            )));
        }
        let mut rk = self.ranks[rank].lock().unwrap();
        if !rk.pending {
            return Err(Error::invariant(format!(
                "rank {rank} completing a ring round it never started"
            )));
        }
        rk.pending = false;
        let my_gen = rk.generation;
        if token.generation() != my_gen {
            return Err(Error::invariant(format!(
                "rank {rank} completing round {}, but the ring is at round {my_gen}",
                token.generation()
            )));
        }
        if self.poisoned.load(Ordering::SeqCst) {
            return Err(self.poison_fault(my_gen));
        }
        let contribution = match token.take_stash() {
            Some(Message::Sparse(s)) => s,
            _ => {
                return Err(Error::invariant(
                    "ring sparse reduce token lost its stashed contribution",
                ))
            }
        };
        let n = self.n;
        let len = round.union_len;
        // the begin-time injector discards open this rank's residual
        residual.clear();
        {
            let stash = &mut rk.residual_stash;
            for (&i, &v) in stash.idx.iter().zip(stash.val.iter()) {
                residual.push_entry(i, v);
            }
            stash.clear();
        }
        if n == 1 {
            reduce_sparse_contributions_with(
                1,
                len,
                |_| (&contribution.idx, &contribution.val),
                round.shard_k,
                scratch,
                out,
                |_, i, v| residual.push_entry(i, v),
            );
            canonicalize_residual(residual, scratch);
            rk.generation = my_gen.wrapping_add(1);
            return Ok(());
        }
        let deadline = Instant::now() + self.timeout;
        // phase 1 — sparse reduce-scatter: forward the partial merged at
        // the previous step (step 0's injection went out in begin),
        // receive chunk (rank - 2 - s) mod n, merge-add the own slice
        // (partial first — the canonical per-coordinate order), and
        // re-apply the cap, keeping the discards as this rank's
        // residual; after n - 1 steps `carry` holds this rank's fully
        // reduced shard entries
        let mut carry = SparseVec::new();
        for step in 0..n - 1 {
            if step > 0 {
                let chunk = (rank + 2 * n - 1 - step) % n;
                let sv = std::mem::take(&mut carry);
                let bytes = sv.payload_bytes();
                rk.tx_right
                    .send(Hop::SparseChunk {
                        generation: my_gen,
                        step,
                        chunk,
                        sv,
                    })
                    .map_err(|_| {
                        Error::invariant("ring link disconnected — transport dropped")
                    })?;
                self.obs[rank].payload_tx(bytes);
            }
            let chunk = (rank + 2 * n - 2 - step) % n;
            let (cs, ce) = shard_bounds(len, n, chunk);
            let mut partial =
                self.recv_sparse_chunk(rank, &mut rk, deadline, my_gen, step, chunk, (cs, ce))?;
            let (ci, cv) = contribution.range(cs, ce);
            merge_add_sparse(&partial.idx, &partial.val, ci, cv, &mut scratch.merged);
            std::mem::swap(&mut partial, &mut scratch.merged);
            if round.shard_k > 0 && partial.len() > round.shard_k {
                retain_top_k(&mut partial, round.shard_k, &mut scratch.perm, |i, v| {
                    residual.push_entry(i, v)
                });
            }
            carry = partial;
        }
        // phase 2 — all-gather of the n reduced entry lists: stage the
        // own shard, forward reduced chunks for n - 1 more hops, and
        // stage each received one (chunks arrive in ring order, not
        // position order, so `out` is assembled chunk by chunk at the
        // end)
        while rk.shard_parts.len() < n {
            rk.shard_parts.push(SparseVec::new());
        }
        rk.shard_parts[rank].copy_from(&carry.idx, &carry.val);
        for t in 0..n - 1 {
            let send_chunk = (rank + n - t) % n;
            let sv = std::mem::take(&mut carry);
            let bytes = sv.payload_bytes();
            rk.tx_right
                .send(Hop::SparseChunk {
                    generation: my_gen,
                    step: n - 1 + t,
                    chunk: send_chunk,
                    sv,
                })
                .map_err(|_| Error::invariant("ring link disconnected — transport dropped"))?;
            self.obs[rank].payload_tx(bytes);
            let chunk = (rank + 2 * n - 1 - t) % n;
            let (cs, ce) = shard_bounds(len, n, chunk);
            let sv = self
                .recv_sparse_chunk(rank, &mut rk, deadline, my_gen, n - 1 + t, chunk, (cs, ce))?;
            rk.shard_parts[chunk].copy_from(&sv.idx, &sv.val);
            carry = sv;
        }
        // absorb the final buffer back into the free list — the twin of
        // the pop in begin, so steady-state rounds recirculate buffers
        let spare = std::mem::take(&mut carry);
        rk.sparse_free.push(spare);
        // assemble: shard c's positions all precede shard c+1's, so a
        // chunk-order walk lands `out` sorted
        out.clear();
        for c in 0..n {
            let p = &mut rk.shard_parts[c];
            out.idx.extend_from_slice(&p.idx);
            out.val.extend_from_slice(&p.val);
            p.clear();
        }
        canonicalize_residual(residual, scratch);
        rk.generation = my_gen.wrapping_add(1);
        Ok(())
    }

    fn rsag_sparse_abandon(&self, rank: usize, token: RoundToken, round: SparseRound) {
        // peers mid-reduce depend on this rank's 2(n-1) hops: run the
        // round to completion and discard the result; poison the ring
        // if it is already broken so nobody waits out a silence
        let mut scratch = SparseReduceScratch::new();
        let mut out = SparseVec::new();
        let mut residual = SparseVec::new();
        if self
            .rsag_sparse_complete(rank, token, round, &mut scratch, &mut out, &mut residual)
            .is_err()
        {
            self.abort();
        }
    }

    fn abort(&self) {
        self.poison(None);
    }

    fn abort_from(&self, rank: usize) {
        self.poison(Some(rank));
    }

    fn epoch(&self) -> u64 {
        self.epoch
    }

    fn counters(&self, rank: usize) -> Option<&ObsCounters> {
        self.obs.get(rank)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::transport::Endpoint;
    use crate::coordinator::SelectOutput;

    #[test]
    fn single_rank_allgather_is_identity() {
        let tp = RingLocal::new(1);
        let ep = Endpoint::new(0, &tp);
        assert_eq!(ep.allgather_f64(2.5).unwrap(), vec![2.5]);
        assert_eq!(ep.allgather_f64(3.5).unwrap(), vec![3.5]);
    }

    #[test]
    fn multi_rank_allgather_is_rank_indexed_over_rounds() {
        let n = 4;
        let rounds = 25;
        let tp = Arc::new(RingLocal::new(n));
        let mut handles = Vec::new();
        for rank in 0..n {
            let tp = tp.clone();
            handles.push(std::thread::spawn(move || {
                let ep = Endpoint::new(rank, tp.as_ref());
                for round in 0..rounds {
                    let mine = (rank * 1000 + round) as f64;
                    let got = ep.allgather_f64(mine).unwrap();
                    let want: Vec<f64> = (0..n).map(|r| (r * 1000 + round) as f64).collect();
                    assert_eq!(got, want, "rank {rank} round {round}");
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn payloads_are_shared_not_copied() {
        // a hop moves the Arc, so the received entry is the sender's
        // buffer — the ring twin keeps the zero-copy payload property
        let n = 2;
        let tp = Arc::new(RingLocal::new(n));
        let payload = Arc::new(vec![1.0f32, 2.0]);
        let sent = Arc::clone(&payload);
        let tp1 = tp.clone();
        let h = std::thread::spawn(move || tp1.allgather(1, Message::Floats(sent)).unwrap());
        let board0 = tp
            .allgather(0, Message::Floats(Arc::new(vec![0.5])))
            .unwrap();
        h.join().unwrap();
        match &board0[1] {
            Message::Floats(v) => {
                assert!(Arc::ptr_eq(v, &payload), "payload must not be copied")
            }
            other => panic!("wrong envelope {other:?}"),
        }
    }

    #[test]
    fn board_slab_is_recycled_across_rounds() {
        let tp = RingLocal::new(1);
        let first = tp.allgather(0, Message::Scalar(1.0)).unwrap();
        let first_ptr = Arc::as_ptr(&first);
        drop(first);
        let second = tp.allgather(0, Message::Scalar(2.0)).unwrap();
        assert_eq!(
            Arc::as_ptr(&second),
            first_ptr,
            "dropped board slab must be reused"
        );
        // a retained board is never clobbered
        let held = tp.allgather(0, Message::Scalar(3.0)).unwrap();
        let next = tp.allgather(0, Message::Scalar(4.0)).unwrap();
        assert!(!Arc::ptr_eq(&held, &next));
        assert_eq!(&held[..], &[Message::Scalar(3.0)]);
    }

    #[test]
    fn selections_roundtrip() {
        let n = 3;
        let tp = Arc::new(RingLocal::new(n));
        let mk = |r: usize| SelectOutput {
            idx: vec![r as u32, 10 + r as u32],
            val: vec![r as f32, -(r as f32)],
        };
        let mut handles = Vec::new();
        for rank in 0..n {
            let tp = tp.clone();
            let mine = Arc::new(mk(rank));
            handles.push(std::thread::spawn(move || {
                let ep = Endpoint::new(rank, tp.as_ref());
                ep.allgather_select(mine).unwrap()
            }));
        }
        for h in handles {
            let outs = h.join().unwrap();
            assert_eq!(outs.len(), n);
            for (r, o) in outs.iter().enumerate() {
                assert_eq!(o.as_ref(), &mk(r));
            }
        }
    }

    #[test]
    fn rsag_matches_the_canonical_shard_order_over_rounds() {
        use crate::collectives::allreduce::reduce_contributions_rsag_with;

        // order-probe data: ulp(1e8) = 8 for f32, so 1e8 + 1.0 == 1e8
        // and the summation order is observable in the result bits
        let probe = |rank: usize, round: usize, len: usize| -> Vec<f32> {
            (0..len)
                .map(|i| [1.0e8f32, 1.0, -1.0e8][(rank + i + round) % 3])
                .collect()
        };
        let n = 4;
        let len = 11;
        let rounds = 8;
        let tp = Arc::new(RingLocal::new(n));
        let mut handles = Vec::new();
        for rank in 0..n {
            let tp = tp.clone();
            handles.push(std::thread::spawn(move || {
                let mut shards = FloatBufPool::new();
                let mut out = Vec::new();
                for round in 0..rounds {
                    let mine = Arc::new(probe(rank, round, len));
                    if round % 2 == 0 {
                        tp.reduce_scatter_allgather(rank, mine, &mut shards, &mut out)
                            .unwrap();
                    } else {
                        // split-phase path lands the identical bits
                        let token = tp.rsag_begin(rank, mine).unwrap();
                        tp.rsag_complete(rank, token, &mut shards, &mut out)
                            .unwrap();
                    }
                    let mut want = Vec::new();
                    let parts: Vec<Vec<f32>> =
                        (0..n).map(|r| probe(r, round, len)).collect();
                    reduce_contributions_rsag_with(n, len, |r| &parts[r], &mut want);
                    let got: Vec<u32> = out.iter().map(|v| v.to_bits()).collect();
                    let want: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
                    assert_eq!(got, want, "rank {rank} round {round}");
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn rsag_rounds_interleave_with_allgather_rounds() {
        let n = 3;
        let len = 6;
        let tp = Arc::new(RingLocal::new(n));
        let mut handles = Vec::new();
        for rank in 0..n {
            let tp = tp.clone();
            handles.push(std::thread::spawn(move || {
                let ep = Endpoint::new(rank, tp.as_ref());
                let mut shards = FloatBufPool::new();
                let mut out = Vec::new();
                for round in 0..6 {
                    let mine = Arc::new(vec![(rank + round) as f32; len]);
                    tp.reduce_scatter_allgather(rank, mine, &mut shards, &mut out)
                        .unwrap();
                    let want = (0..n).map(|r| (r + round) as f32).sum::<f32>();
                    assert!(out.iter().all(|&v| v == want), "rank {rank} round {round}");
                    // a board round between reduce rounds must still work
                    let got = ep.allgather_f64(rank as f64).unwrap();
                    assert_eq!(got, (0..n).map(|r| r as f64).collect::<Vec<_>>());
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn single_rank_rsag_is_identity() {
        let tp = RingLocal::new(1);
        let mut shards = FloatBufPool::new();
        let mut out = Vec::new();
        tp.reduce_scatter_allgather(0, Arc::new(vec![1.0, 2.0]), &mut shards, &mut out)
            .unwrap();
        assert_eq!(out, vec![1.0, 2.0]);
        tp.reduce_scatter_allgather(0, Arc::new(vec![3.0]), &mut shards, &mut out)
            .unwrap();
        assert_eq!(out, vec![3.0]);
    }

    #[test]
    fn abort_unblocks_waiters_with_error() {
        let n = 2;
        let tp = Arc::new(RingLocal::new(n));
        let tp2 = tp.clone();
        let waiter = std::thread::spawn(move || {
            let ep = Endpoint::new(0, tp2.as_ref());
            ep.allgather_f64(1.0)
        });
        std::thread::sleep(Duration::from_millis(20));
        tp.abort();
        assert!(
            waiter.join().unwrap().is_err(),
            "poisoned ring must error, not hang"
        );
        // later calls fail fast
        let ep = Endpoint::new(1, tp.as_ref());
        assert!(ep.allgather_f64(2.0).is_err());
    }

    #[test]
    fn attributed_abort_surfaces_peer_lost_and_counts_once() {
        let n = 2;
        let tp = Arc::new(RingLocal::new(n));
        assert_eq!((tp.as_ref() as &dyn Transport).epoch(), 0);
        let tp2 = tp.clone();
        let waiter = std::thread::spawn(move || {
            let ep = Endpoint::new(0, tp2.as_ref());
            ep.allgather_f64(1.0)
        });
        std::thread::sleep(Duration::from_millis(20));
        tp.abort_from(1);
        let err = waiter.join().unwrap().unwrap_err();
        assert!(err.is_membership_fault(), "{err}");
        assert!(err.to_string().contains("peer rank 1 lost"), "{err}");
        // later calls fail fast with the same attribution, and repeated
        // defensive aborts keep the counter at the one poisoning
        tp.abort();
        let err = tp.allgather(1, Message::Scalar(0.0)).unwrap_err();
        assert!(err.to_string().contains("peer rank 1 lost"), "{err}");
        assert_eq!(tp.counters(0).unwrap().snapshot().aborts, 1);
        assert_eq!(tp.counters(1).unwrap().snapshot().aborts, 1);
    }

    #[test]
    fn epoch_constructor_stamps_the_transport() {
        let tp = RingLocal::with_timeout_at_epoch(1, Duration::from_secs(5), 2);
        assert_eq!((&tp as &dyn Transport).epoch(), 2);
        let ep = Endpoint::new(0, &tp);
        assert_eq!(ep.allgather_f64(7.0).unwrap(), vec![7.0]);
    }

    #[test]
    fn silent_neighbor_times_out() {
        let tp = RingLocal::with_timeout(2, Duration::from_millis(100));
        // rank 1 never deposits; rank 0 must surface a deadline error
        let err = tp
            .allgather(0, Message::Scalar(0.0))
            .unwrap_err()
            .to_string();
        assert!(err.contains("deadline") || err.contains("silent"), "{err}");
    }

    #[test]
    fn out_of_range_rank_rejected() {
        let tp = RingLocal::new(2);
        assert!(tp.allgather(5, Message::Scalar(0.0)).is_err());
    }

    #[test]
    fn sparse_rsag_matches_the_lockstep_twin_bit_for_bit() {
        use crate::collectives::sparse_shard_allreduce_lockstep;

        // strided disjoint selections with order-probe magnitudes: every
        // shard sees entries from several ranks, caps force real
        // re-selection, and the f32 bits expose any order divergence
        let probe = |rank: usize, round: usize, n: usize, len: usize| -> SparseVec {
            const VALS: [f32; 3] = [1.0e8, 1.0, -1.0e8];
            let mut sv = SparseVec::new();
            let mut pos = rank;
            while pos < len {
                sv.push(pos as u32, VALS[(rank + pos + round) % 3]);
                pos += n;
            }
            sv
        };
        let n = 4;
        let len = 13;
        let rounds = 8;
        let tp = Arc::new(RingLocal::new(n));
        let mut handles = Vec::new();
        for rank in 0..n {
            let tp = tp.clone();
            handles.push(std::thread::spawn(move || {
                let mut scratch = SparseReduceScratch::new();
                let mut out = SparseVec::new();
                let mut residual = SparseVec::new();
                for round in 0..rounds {
                    let shard_k = if round % 3 == 0 { 0 } else { 2 };
                    let rd = SparseRound {
                        union_len: len,
                        shard_k,
                    };
                    let mine = Arc::new(probe(rank, round, n, len));
                    if round % 2 == 0 {
                        tp.rsag_sparse(rank, mine, rd, &mut scratch, &mut out, &mut residual)
                            .unwrap();
                    } else {
                        // split-phase path lands the identical bits
                        let token = tp.rsag_sparse_begin(rank, mine, rd).unwrap();
                        tp.rsag_sparse_complete(
                            rank,
                            token,
                            rd,
                            &mut scratch,
                            &mut out,
                            &mut residual,
                        )
                        .unwrap();
                    }
                    let contribs: Vec<SparseVec> =
                        (0..n).map(|r| probe(r, round, n, len)).collect();
                    let net = CostModel::paper_testbed(n);
                    let mut tw_scratch = SparseReduceScratch::new();
                    let mut tw_entries = SparseVec::new();
                    let mut tw_reduced = Vec::new();
                    let mut tw_residuals: Vec<SparseVec> =
                        (0..n).map(|_| SparseVec::new()).collect();
                    sparse_shard_allreduce_lockstep(
                        &contribs,
                        len,
                        shard_k,
                        &net,
                        &mut tw_scratch,
                        &mut tw_entries,
                        &mut tw_reduced,
                        &mut tw_residuals,
                    );
                    assert_eq!(out.idx, tw_entries.idx, "rank {rank} round {round}");
                    let got: Vec<u32> = out.val.iter().map(|x| x.to_bits()).collect();
                    let want: Vec<u32> =
                        tw_entries.val.iter().map(|x| x.to_bits()).collect();
                    assert_eq!(got, want, "rank {rank} round {round} values");
                    assert_eq!(
                        residual.idx, tw_residuals[rank].idx,
                        "rank {rank} round {round} residual positions"
                    );
                    let got: Vec<u32> =
                        residual.val.iter().map(|x| x.to_bits()).collect();
                    let want: Vec<u32> =
                        tw_residuals[rank].val.iter().map(|x| x.to_bits()).collect();
                    assert_eq!(got, want, "rank {rank} round {round} residual values");
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn sparse_rsag_counters_match_the_sparse_link_model() {
        // full-overlap contributions with no cap keep every partial and
        // reduced chunk exactly shard-sized, so each rank's payload
        // traffic must equal the model's 2(n-1)/n · E · 8 B prediction
        // byte-exact (len divisible by n keeps shards equal)
        let n = 4;
        let len = 12;
        let tp = Arc::new(RingLocal::new(n));
        let rd = SparseRound {
            union_len: len,
            shard_k: 0,
        };
        let mut handles = Vec::new();
        for rank in 0..n {
            let tp = tp.clone();
            handles.push(std::thread::spawn(move || {
                let mut sv = SparseVec::new();
                for i in 0..len {
                    sv.push(i as u32, (rank + 1) as f32);
                }
                let mut scratch = SparseReduceScratch::new();
                let mut out = SparseVec::new();
                let mut residual = SparseVec::new();
                tp.rsag_sparse(rank, Arc::new(sv), rd, &mut scratch, &mut out, &mut residual)
                    .unwrap();
                assert_eq!(out.len(), len, "uncapped full overlap keeps the union");
                assert!(residual.is_empty());
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let net = CostModel::paper_testbed(n);
        let want = net.rsag_sparse_link_bytes_ring(len) as u64;
        for rank in 0..n {
            let c = tp.counters(rank).unwrap().snapshot();
            assert_eq!(c.payload_tx_bytes, want, "rank {rank} tx");
            assert_eq!(c.payload_rx_bytes, want, "rank {rank} rx");
            assert_eq!(c.rounds_rsag, 1);
            assert_eq!(c.rounds_allgather, 0);
        }
    }

    #[test]
    fn counters_match_the_ring_link_model_per_round() {
        // every rank contributes B bytes; each ring link must carry
        // exactly (n-1)·B per all-gather and 2(n-1)/n·V per rsag — the
        // cost model's link-byte predictions, measured not asserted
        let n = 4;
        let len = 8; // divisible by n, so shard arithmetic is exact
        let tp = Arc::new(RingLocal::new(n));
        let mut handles = Vec::new();
        for rank in 0..n {
            let tp = tp.clone();
            handles.push(std::thread::spawn(move || {
                let mut shards = FloatBufPool::new();
                let mut out = Vec::new();
                tp.allgather(rank, Message::Floats(Arc::new(vec![0.0f32; len])))
                    .unwrap();
                tp.reduce_scatter_allgather(
                    rank,
                    Arc::new(vec![1.0f32; len]),
                    &mut shards,
                    &mut out,
                )
                .unwrap();
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let b = len * CostModel::DENSE_ENTRY_BYTES;
        let net = CostModel::paper_testbed(n);
        for rank in 0..n {
            let c = tp.counters(rank).unwrap().snapshot();
            let want =
                (net.allgather_link_bytes_ring(b) + net.rsag_link_bytes_ring(b)) as u64;
            assert_eq!(c.payload_tx_bytes, want, "rank {rank} tx");
            assert_eq!(c.payload_rx_bytes, want, "rank {rank} rx");
            assert_eq!(c.rounds_allgather, 1);
            assert_eq!(c.rounds_rsag, 1);
            assert_eq!(c.wire_tx_bytes, 0, "no socket, no wire account");
        }
    }
}
