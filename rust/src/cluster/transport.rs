//! Message transport between rank workers.
//!
//! [`Transport`] is the abstraction the per-rank collectives run over: a
//! synchronous, rank-addressed all-gather (every collective in Alg. 1 —
//! metadata all-gather, padded payload all-gather, sparse all-reduce
//! contributions, leader broadcast — decomposes into "each rank
//! contributes one message, every rank receives the rank-indexed
//! vector"). Implementations move the bytes; the α–β [`CostModel`]
//! separately charges what the operation *would* cost on the modeled
//! wire, so data movement and wire-clock accounting stay decoupled.
//!
//! **Zero-copy fan-out.** Payloads are reference-counted
//! ([`Message::Selection`] holds `Arc<SelectOutput>`, [`Message::Floats`]
//! holds `Arc<Vec<f32>>`) and [`Transport::allgather`] returns the whole
//! rank-indexed board as one shared `Arc<[Message]>` slab. Handing the
//! board to n ranks is therefore n refcount bumps — O(n) — instead of n
//! deep copies of an n-message board — O(n²·k) element copies, which is
//! what the naive `Vec<Message>` design cost per round. The *modeled*
//! α–β clock still charges the real byte volume each collective would
//! move on a wire (the padded payload, every rank's contribution), so
//! traces are bit-identical to the copying implementation; only the
//! harness overhead changes.
//!
//! **Split-phase collectives.** Every all-gather also exists as a
//! nonblocking start/finish pair so the engines can overlap iteration
//! t+1's compute with iteration t's communication (step-level
//! pipelining): [`Endpoint::allgather_start`] (or `allgather_start` on
//! `dyn Transport`) deposits/sends this rank's contribution immediately
//! and returns a [`PendingRound`]; [`PendingRound::finish`] blocks for
//! the board. The contract, pinned for all four transports by the
//! split-phase battery in `rust/tests/transport_conformance.rs`:
//!
//! * the contribution is genuinely *in flight* at start — the socket
//!   transports write the contribution (star client) or the first ring
//!   chunk eagerly, so peers can make progress during the gap;
//! * rounds are generation-stamped: finish returns exactly the round it
//!   started, and cross-round mixing is a typed error;
//! * at most ONE round may be outstanding per rank — a second start (or
//!   a blocking all-gather) before finish is a typed error;
//! * [`Transport::abort`] between start and finish poisons the finish
//!   within the IO deadline, never a hang;
//! * dropping a [`PendingRound`] without finishing abandons the round
//!   without wedging peers (the drop hook forwards/drains whatever the
//!   peers still need — the deposit made at start always stands).
//!
//! Implementations override [`Transport::allgather_begin`] /
//! [`Transport::allgather_complete`] / [`Transport::allgather_abandon`];
//! the blocking [`Transport::allgather`] is begin + complete, and a
//! transport that overrides nothing gets a correct (if overlap-free)
//! default that completes the round eagerly at start.
//!
//! **The reduce-scatter → all-gather collective.** The all-gather moves
//! the *full* n-message board to every rank — O(n·k) received per rank,
//! the gradient build-up pathology re-introduced at the collective
//! layer. [`Transport::reduce_scatter_allgather`] is the second
//! collective form (`--collective rsag`): each rank owns the index
//! shard matching its position ([`shard_bounds`]), incoming
//! contributions are reduced *for that shard only* in flight, and then
//! just the n reduced shards are all-gathered — `2(n-1)/n·V` received
//! per rank, flat in n, matching the ring α–β form `2(n-1)·α +
//! 2(n-1)/n·V·β` the modeled clock always charged for the value
//! reduce. Shard sums accumulate in the canonical ring order
//! ([`rsag_rank_order`]: shard c starts at rank c+1 and its owner adds
//! last), which every implementation shares, so results are bit-exact
//! across transports and engines — but differ in low bits from the
//! all-gather collective's rank-order sum, as with any real
//! reduce-scatter. The split-phase pair
//! ([`Transport::rsag_begin`] / [`Transport::rsag_complete`], wrapped
//! by [`PendingReduce`]) carries the exact [`PendingRound`] contract:
//! contribution in flight at begin, generation-stamped, one
//! outstanding round per rank with typed double-start rejection
//! (shared with the all-gather rounds — a rank has ONE in-flight round
//! of either kind), abort-poisoned finish, drop-without-finish safe
//! (abandon drains the round so peers never wedge). The default
//! implementation rides the split-phase all-gather and reduces the
//! full board locally in canonical order — correct for any transport,
//! without the bandwidth win; the in-tree transports override it
//! natively.
//!
//! [`LocalTransport`] is the in-process implementation: a rendezvous for
//! one OS thread per rank, built on a generation-counted slot board
//! (mutex + condvar). Every round each rank deposits its message; the
//! last arrival publishes the full board and wakes the others. A rank
//! can only enter round `g+1` after consuming round `g`, so the
//! published board is never overwritten early. Published slabs are
//! double-buffered and recycled once every rank has moved two rounds on,
//! so a steady-state round performs **zero heap allocations** — split-
//! phase rounds included; [`RoundToken`] and [`PendingRound`] are plain
//! stack values (`rust/tests/alloc_regression.rs` pins this). A failed
//! worker poisons the transport ([`Transport::abort`]) so peers error
//! out instead of deadlocking at the rendezvous.
//!
//! [CostModel]: crate::collectives::CostModel

use crate::collectives::allreduce::{reduce_contributions_rsag_with, rsag_rank_order, shard_bounds};
use crate::collectives::sparse::{
    canonicalize_residual, reduce_sparse_contributions_with, SparseReduceScratch, SparseVec,
};
use crate::collectives::CostModel;
use crate::coordinator::SelectOutput;
use crate::error::{Error, Result};
use crate::obs::{FlightRecorder, ObsCounters};
use std::sync::atomic::{AtomicBool, Ordering::Relaxed};
use std::sync::{Arc, Condvar, Mutex};

/// One rank's contribution to a collective round. Payloads are behind
/// `Arc`s so boards fan out by refcount, not by copy; `Clone` is O(1).
#[derive(Clone, Debug, PartialEq)]
pub enum Message {
    /// Selected (idx, val) pairs — the payload all-gather (its length is
    /// simultaneously the `k_i` metadata).
    Selection(Arc<SelectOutput>),
    /// Dense f32 payload — sparse all-reduce contributions.
    Floats(Arc<Vec<f32>>),
    /// One f64 — timing metadata and diagnostics (select wall time,
    /// error norms).
    Scalar(f64),
    /// Sorted `(position, value)` entry list — the truly sparse rsag
    /// contribution (`--sparse-shards`): positions index the round's
    /// union, and only the rank's own selections are present.
    Sparse(Arc<SparseVec>),
}

impl Message {
    /// Model-level payload bytes of this message — the same units the
    /// [`CostModel`] link-byte predictions are stated in (8 B per
    /// sparse (idx, val) entry, 4 B per dense f32, 8 B per scalar).
    /// The [`ObsCounters`] payload accounts bump by exactly this, which
    /// is what makes measured payload traffic comparable (and on the
    /// socket transports: byte-equal) to the model's predictions.
    pub fn payload_bytes(&self) -> usize {
        match self {
            Message::Selection(s) => s.idx.len() * CostModel::SPARSE_ENTRY_BYTES,
            Message::Floats(v) => v.len() * CostModel::DENSE_ENTRY_BYTES,
            Message::Scalar(_) => std::mem::size_of::<f64>(),
            Message::Sparse(s) => s.payload_bytes(),
        }
    }
}

/// Opaque in-flight state of a split-phase all-gather, handed from
/// [`Transport::allgather_begin`] to [`Transport::allgather_complete`].
/// Generation-stamped so a finish can never return a different round
/// than its start. A plain stack value — starting and finishing a round
/// allocates nothing.
pub struct RoundToken {
    generation: u64,
    /// Board already completed at begin (the default emulation for
    /// transports that don't implement a native split phase).
    ready: Option<Arc<[Message]>>,
    /// This rank's own contribution, when the transport must defer even
    /// the send to complete-time (the TCP star's hub receives before it
    /// sends anything).
    stash: Option<Message>,
}

impl RoundToken {
    /// Token for a round whose completion work all happens at finish.
    pub fn deferred(generation: u64) -> Self {
        RoundToken {
            generation,
            ready: None,
            stash: None,
        }
    }

    /// Like [`RoundToken::deferred`], but carrying the rank's own
    /// contribution to complete-time.
    pub fn deferred_with_stash(generation: u64, msg: Message) -> Self {
        RoundToken {
            generation,
            ready: None,
            stash: Some(msg),
        }
    }

    /// Token for a round that was completed eagerly at begin.
    pub fn ready(generation: u64, board: Arc<[Message]>) -> Self {
        RoundToken {
            generation,
            ready: Some(board),
            stash: None,
        }
    }

    /// The round this token belongs to (transport generation counter).
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Take the eagerly-completed board, if any.
    pub fn take_ready(&mut self) -> Option<Arc<[Message]>> {
        self.ready.take()
    }

    /// Take the stashed own-contribution, if any.
    pub fn take_stash(&mut self) -> Option<Message> {
        self.stash.take()
    }
}

/// One in-flight split-phase all-gather: returned by
/// [`Endpoint::allgather_start`] / `allgather_start` on `dyn Transport`,
/// consumed by [`PendingRound::finish`]. Dropping it without finishing
/// abandons the round safely ([`Transport::allgather_abandon`]): the
/// contribution made at start stands, peers complete normally, and this
/// rank may start the next round afterwards.
pub struct PendingRound<'a> {
    tp: &'a dyn Transport,
    rank: usize,
    token: Option<RoundToken>,
}

impl<'a> PendingRound<'a> {
    /// Start a split-phase all-gather for `rank` over `tp`: the
    /// contribution is deposited / put on the wire before this returns.
    pub fn start(tp: &'a dyn Transport, rank: usize, msg: Message) -> Result<Self> {
        let token = tp.allgather_begin(rank, msg)?;
        Ok(PendingRound {
            tp,
            rank,
            token: Some(token),
        })
    }

    /// The rank this round was started for.
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// The round's generation stamp.
    pub fn generation(&self) -> u64 {
        self.token
            .as_ref()
            .map(RoundToken::generation)
            .unwrap_or(0)
    }

    /// Block for the round's board. Abort-aware and deadline-bounded
    /// exactly like the blocking all-gather: a poisoned or wedged round
    /// is a typed error, never a hang.
    pub fn finish(mut self) -> Result<Arc<[Message]>> {
        let token = self
            .token
            .take()
            .expect("finish consumes the pending round exactly once");
        self.tp.allgather_complete(self.rank, token)
    }
}

impl Drop for PendingRound<'_> {
    fn drop(&mut self) {
        if let Some(token) = self.token.take() {
            self.tp.allgather_abandon(self.rank, token);
        }
    }
}

/// One in-flight split-phase reduce-scatter → all-gather: returned by
/// [`Endpoint::rsag_start`] / `rsag_start` on `dyn Transport`, consumed
/// by [`PendingReduce::finish`], which lands the canonically-ordered
/// SUM of every rank's contribution in the caller's buffer. Dropping it
/// without finishing abandons the round safely
/// ([`Transport::rsag_abandon`] drains both phases, so peers mid-reduce
/// never wedge) and this rank may start the next round afterwards.
pub struct PendingReduce<'a> {
    tp: &'a dyn Transport,
    rank: usize,
    token: Option<RoundToken>,
}

impl<'a> PendingReduce<'a> {
    /// Start a split-phase reduce-scatter → all-gather for `rank` over
    /// `tp`: the contribution is deposited / put on the wire before
    /// this returns.
    pub fn start(tp: &'a dyn Transport, rank: usize, contribution: Arc<Vec<f32>>) -> Result<Self> {
        let token = tp.rsag_begin(rank, contribution)?;
        Ok(PendingReduce {
            tp,
            rank,
            token: Some(token),
        })
    }

    /// The rank this round was started for.
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// The round's generation stamp.
    pub fn generation(&self) -> u64 {
        self.token
            .as_ref()
            .map(RoundToken::generation)
            .unwrap_or(0)
    }

    /// Block for the reduced vector: reduce this rank's shard in
    /// flight, all-gather the n reduced shards, and assemble the full
    /// canonically-ordered SUM into `out`. `shards` backs the reduced-
    /// shard message so steady-state rounds allocate nothing.
    /// Abort-aware and deadline-bounded exactly like
    /// [`PendingRound::finish`].
    pub fn finish(mut self, shards: &mut FloatBufPool, out: &mut Vec<f32>) -> Result<()> {
        let token = self
            .token
            .take()
            .expect("finish consumes the pending reduce exactly once");
        self.tp.rsag_complete(self.rank, token, shards, out)
    }
}

impl Drop for PendingReduce<'_> {
    fn drop(&mut self) {
        if let Some(token) = self.token.take() {
            self.tp.rsag_abandon(self.rank, token);
        }
    }
}

/// The shared envelope of one truly sparse rsag round
/// (`--sparse-shards`): every rank derives the same values from the
/// round's gathered selections, so the transports can validate and
/// shard without any extra negotiation.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SparseRound {
    /// Length of the round's union index space — sparse positions are
    /// `u32` offsets into `0..union_len`, sharded by
    /// [`shard_bounds`](crate::collectives::shard_bounds).
    pub union_len: usize,
    /// Per-shard re-selection cap: after each canonical merge a shard
    /// holding more than `shard_k` entries is re-top-k'd
    /// ([`crate::collectives::retain_top_k`]) and the discards become
    /// the merging rank's residual. `0` disables re-selection (shards
    /// grow to the union of their contributions).
    pub shard_k: usize,
}

/// One in-flight split-phase truly sparse reduce-scatter → all-gather:
/// returned by [`Endpoint::rsag_sparse_start`] / `rsag_sparse_start` on
/// `dyn Transport`, consumed by [`PendingSparseReduce::finish`], which
/// lands the canonically reduced, possibly re-top-k'd `(index, value)`
/// entry list in `out` and this rank's re-selection discards in
/// `residual`. Dropping it without finishing abandons the round safely
/// ([`Transport::rsag_sparse_abandon`]) and this rank may start the
/// next round afterwards.
pub struct PendingSparseReduce<'a> {
    tp: &'a dyn Transport,
    rank: usize,
    round: SparseRound,
    token: Option<RoundToken>,
}

impl<'a> PendingSparseReduce<'a> {
    /// Start a split-phase sparse rsag for `rank` over `tp`: the sparse
    /// contribution is deposited / put on the wire before this returns.
    pub fn start(
        tp: &'a dyn Transport,
        rank: usize,
        contribution: Arc<SparseVec>,
        round: SparseRound,
    ) -> Result<Self> {
        let token = tp.rsag_sparse_begin(rank, contribution, round)?;
        Ok(PendingSparseReduce {
            tp,
            rank,
            round,
            token: Some(token),
        })
    }

    /// The rank this round was started for.
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// The round's generation stamp.
    pub fn generation(&self) -> u64 {
        self.token
            .as_ref()
            .map(RoundToken::generation)
            .unwrap_or(0)
    }

    /// Block for the reduced entries: `out` receives the canonically
    /// reduced (and per-hop re-selected, when `shard_k > 0`) entry
    /// list, `residual` this rank's discards in canonical form.
    /// Abort-aware and deadline-bounded exactly like
    /// [`PendingReduce::finish`].
    pub fn finish(
        mut self,
        scratch: &mut SparseReduceScratch,
        out: &mut SparseVec,
        residual: &mut SparseVec,
    ) -> Result<()> {
        let token = self
            .token
            .take()
            .expect("finish consumes the pending sparse reduce exactly once");
        self.tp
            .rsag_sparse_complete(self.rank, token, self.round, scratch, out, residual)
    }
}

impl Drop for PendingSparseReduce<'_> {
    fn drop(&mut self) {
        if let Some(token) = self.token.take() {
            self.tp.rsag_sparse_abandon(self.rank, token, self.round);
        }
    }
}

impl<'t> dyn Transport + 't {
    /// Nonblocking start of an all-gather round (split-phase form of
    /// [`Transport::allgather`]): rank `rank`'s contribution is
    /// deposited / put on the wire immediately; `finish()` on the
    /// returned handle blocks for the rank-indexed board. At most one
    /// round may be in flight per rank.
    pub fn allgather_start(&self, rank: usize, msg: Message) -> Result<PendingRound<'_>> {
        PendingRound::start(self, rank, msg)
    }

    /// Nonblocking start of a reduce-scatter → all-gather round
    /// (split-phase form of [`Transport::reduce_scatter_allgather`]).
    /// Shares the one-outstanding-round-per-rank budget with the
    /// all-gather rounds: starting either kind while either kind is in
    /// flight is a typed error.
    pub fn rsag_start(&self, rank: usize, contribution: Arc<Vec<f32>>) -> Result<PendingReduce<'_>> {
        PendingReduce::start(self, rank, contribution)
    }

    /// Nonblocking start of a truly sparse reduce-scatter → all-gather
    /// round (split-phase form of [`Transport::rsag_sparse`]). Shares
    /// the one-outstanding-round-per-rank budget with every other
    /// collective kind.
    pub fn rsag_sparse_start(
        &self,
        rank: usize,
        contribution: Arc<SparseVec>,
        round: SparseRound,
    ) -> Result<PendingSparseReduce<'_>> {
        PendingSparseReduce::start(self, rank, contribution, round)
    }
}

/// Rank-addressed synchronous collectives. Implementations must be
/// callable concurrently from one thread per rank.
pub trait Transport: Send + Sync {
    /// Cluster size.
    fn n_ranks(&self) -> usize;

    /// Synchronous all-gather: rank `rank` contributes `msg` and receives
    /// every rank's message, rank-indexed, as one shared slab. All ranks
    /// must call this the same number of times in the same order
    /// (enforced by construction: workers run identical control flow off
    /// replicated state).
    fn allgather(&self, rank: usize, msg: Message) -> Result<Arc<[Message]>>;

    /// Nonblocking half of a split-phase all-gather: deposit / put rank
    /// `rank`'s contribution in flight and return a generation-stamped
    /// [`RoundToken`] for [`Transport::allgather_complete`]. Native
    /// implementations must reject a second begin before the first
    /// round's complete (or abandon) with a typed error — every in-tree
    /// transport does, and the conformance battery pins it. The default
    /// emulation completes the whole round eagerly (correct but
    /// overlap-free) and, being stateless, cannot track an outstanding
    /// round: under it a "double start" degenerates to two back-to-back
    /// blocking rounds — the same caller-divergence hazard as calling
    /// the blocking [`Transport::allgather`] twice. Override all three
    /// split-phase methods together for the full contract.
    fn allgather_begin(&self, rank: usize, msg: Message) -> Result<RoundToken> {
        Ok(RoundToken::ready(0, self.allgather(rank, msg)?))
    }

    /// Blocking half of a split-phase all-gather: drain the round
    /// started by [`Transport::allgather_begin`] and return its board.
    /// Must honor the same abort-poisoning and IO deadlines as the
    /// blocking [`Transport::allgather`].
    fn allgather_complete(&self, rank: usize, mut token: RoundToken) -> Result<Arc<[Message]>> {
        let _ = rank;
        token.take_ready().ok_or_else(|| {
            Error::invariant(
                "transport handed out a deferred RoundToken without overriding \
                 allgather_complete",
            )
        })
    }

    /// Drop hook for a [`PendingRound`] that is abandoned instead of
    /// finished. Implementations must leave peers able to complete the
    /// round (the contribution from begin stands) and this rank able to
    /// start the next one. The default matches the default begin (the
    /// round already completed — nothing outstanding).
    fn allgather_abandon(&self, rank: usize, token: RoundToken) {
        let _ = (rank, token);
    }

    /// Synchronous reduce-scatter → all-gather: rank `rank` contributes
    /// a dense f32 vector (every rank's must have the same length) and
    /// receives the element-wise SUM over ranks in `out`, summed shard
    /// by shard in the canonical [`rsag_rank_order`]. Unlike the
    /// all-gather + local-reduce path, each rank receives only
    /// `2(n-1)/n` of the vector instead of `n-1` copies of it. `shards`
    /// backs the reduced-shard buffers so steady-state rounds allocate
    /// nothing. The default implementation rides the split-phase
    /// all-gather (correct for any transport, without the bandwidth
    /// win); in-tree transports override it natively.
    fn reduce_scatter_allgather(
        &self,
        rank: usize,
        contribution: Arc<Vec<f32>>,
        shards: &mut FloatBufPool,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        let token = self.rsag_begin(rank, contribution)?;
        self.rsag_complete(rank, token, shards, out)
    }

    /// Nonblocking half of the split-phase reduce-scatter → all-gather:
    /// put rank `rank`'s contribution in flight and return a
    /// generation-stamped [`RoundToken`] for
    /// [`Transport::rsag_complete`]. Carries the exact
    /// [`Transport::allgather_begin`] contract — in particular the
    /// one-outstanding-round-per-rank budget is shared across both
    /// collective kinds. The default delegates to the all-gather begin
    /// (the contribution is in flight whenever the transport's
    /// all-gather begin puts it in flight).
    fn rsag_begin(&self, rank: usize, contribution: Arc<Vec<f32>>) -> Result<RoundToken> {
        self.allgather_begin(rank, Message::Floats(contribution))
    }

    /// Blocking half of the split-phase reduce-scatter → all-gather:
    /// drain the round started by [`Transport::rsag_begin`] and land
    /// the canonically-ordered SUM in `out`. Must honor the same
    /// abort-poisoning and IO deadlines as the all-gather complete. The
    /// default completes the underlying all-gather and reduces the full
    /// board locally in canonical order.
    fn rsag_complete(
        &self,
        rank: usize,
        token: RoundToken,
        shards: &mut FloatBufPool,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        let _ = shards;
        let board = self.allgather_complete(rank, token)?;
        rsag_reduce_board_into(&board, out)
    }

    /// Drop hook for a [`PendingReduce`] that is abandoned instead of
    /// finished. Unlike the all-gather abandon — where the deposit from
    /// begin is all peers ever need — an abandoned reduce must still
    /// run its remaining phases (peers mid-reduce are waiting on this
    /// rank's partials and reduced shard), so the default completes the
    /// round and discards the result; the cold-path scratch allocation
    /// is irrelevant off the steady state. Errors are swallowed: an
    /// aborted or poisoned round has already released the peers.
    fn rsag_abandon(&self, rank: usize, token: RoundToken) {
        let mut shards = FloatBufPool::new();
        let mut out = Vec::new();
        let _ = self.rsag_complete(rank, token, &mut shards, &mut out);
    }

    /// Truly sparse reduce-scatter → all-gather (`--sparse-shards`):
    /// rank `rank` contributes a sorted `(position, value)` entry list
    /// over the round's union index space and receives in `out` the
    /// canonically reduced entries — each shard merged in
    /// [`rsag_rank_order`], re-top-k'd after every merge when
    /// `round.shard_k > 0` — and in `residual` its OWN re-selection
    /// discards (the entries it merged in that a later cap dropped),
    /// canonicalized to a sorted entry list for error feedback. Unlike
    /// the dense rsag, shards travel as entry lists, so the received
    /// volume tracks `2(n-1)/n · entries · 8 B`
    /// ([`CostModel::rsag_sparse_recv_bytes_per_rank`]) instead of
    /// `2(n-1)/n · union_len · 4 B`. Reduced entries and residuals are
    /// bit-exact across every transport because all of them share the
    /// one canonical merge schedule. The default implementation rides
    /// the split-phase all-gather and replays the canonical reduce on
    /// the full board — correct for any transport, without the
    /// bandwidth win; native transports override it.
    fn rsag_sparse(
        &self,
        rank: usize,
        contribution: Arc<SparseVec>,
        round: SparseRound,
        scratch: &mut SparseReduceScratch,
        out: &mut SparseVec,
        residual: &mut SparseVec,
    ) -> Result<()> {
        let token = self.rsag_sparse_begin(rank, contribution, round)?;
        self.rsag_sparse_complete(rank, token, round, scratch, out, residual)
    }

    /// Nonblocking half of the split-phase sparse rsag: put rank
    /// `rank`'s entry list in flight and return a generation-stamped
    /// [`RoundToken`] for [`Transport::rsag_sparse_complete`]. Carries
    /// the exact [`Transport::rsag_begin`] contract, including the
    /// shared one-outstanding-round-per-rank budget. The default
    /// delegates to the all-gather begin.
    fn rsag_sparse_begin(
        &self,
        rank: usize,
        contribution: Arc<SparseVec>,
        round: SparseRound,
    ) -> Result<RoundToken> {
        let _ = round;
        self.allgather_begin(rank, Message::Sparse(contribution))
    }

    /// Blocking half of the split-phase sparse rsag: drain the round
    /// started by [`Transport::rsag_sparse_begin`] and land the reduced
    /// entries in `out` and this rank's canonical residual in
    /// `residual`. Must honor the same abort-poisoning and IO deadlines
    /// as the dense rsag complete. The default completes the underlying
    /// all-gather and replays the canonical reduce on the full board.
    fn rsag_sparse_complete(
        &self,
        rank: usize,
        token: RoundToken,
        round: SparseRound,
        scratch: &mut SparseReduceScratch,
        out: &mut SparseVec,
        residual: &mut SparseVec,
    ) -> Result<()> {
        let board = self.allgather_complete(rank, token)?;
        rsag_sparse_reduce_board_into(&board, rank, round, scratch, out, residual)
    }

    /// Drop hook for a [`PendingSparseReduce`] that is abandoned
    /// instead of finished. As with [`Transport::rsag_abandon`], peers
    /// mid-reduce may still be waiting on this rank's merges, so the
    /// default completes the round into throwaway buffers and discards
    /// the result; errors are swallowed (an aborted round has already
    /// released the peers).
    fn rsag_sparse_abandon(&self, rank: usize, token: RoundToken, round: SparseRound) {
        let mut scratch = SparseReduceScratch::new();
        let mut out = SparseVec::new();
        let mut residual = SparseVec::new();
        let _ = self.rsag_sparse_complete(rank, token, round, &mut scratch, &mut out, &mut residual);
    }

    /// Rendezvous barrier (default: a scalar all-gather).
    fn barrier(&self, rank: usize) -> Result<()> {
        self.allgather(rank, Message::Scalar(0.0)).map(|_| ())
    }

    /// Poison the transport: wake every waiter with an error. Called by a
    /// worker that is about to exit with a failure so peers don't block
    /// forever at the next rendezvous.
    fn abort(&self);

    /// Poison the transport on behalf of a *known* failing rank, so
    /// peers surface [`Error::PeerLost`](crate::error::Error::PeerLost)
    /// naming the culprit instead of the anonymous
    /// [`Error::Poisoned`](crate::error::Error::Poisoned). The elastic
    /// recovery path uses the attribution to report which member died;
    /// the default discards it and poisons anonymously.
    fn abort_from(&self, rank: usize) {
        let _ = rank;
        self.abort();
    }

    /// Membership epoch this transport instance was formed at. Epoch 0
    /// is the initial formation; the elastic recovery path builds a
    /// fresh transport per re-formation, so a transport's epoch is
    /// fixed for its whole lifetime (data frames need no epoch stamp —
    /// fresh channels per epoch isolate epochs naturally).
    fn epoch(&self) -> u64 {
        0
    }

    /// Rank `rank`'s wire counters, when this transport keeps them.
    /// In-process transports index a shared per-rank array; the socket
    /// transports (one instance per rank process) answer only for their
    /// own rank. `None` means "not instrumented" (e.g. test doubles) —
    /// never "zero traffic".
    fn counters(&self, rank: usize) -> Option<&ObsCounters> {
        let _ = rank;
        None
    }

    /// Attach a [`FlightRecorder`] for rank `rank`'s protocol events
    /// (`--obs-flight`). Off by default; the default implementation
    /// drops the recorder — only the socket transports, where a dump has
    /// a postmortem story to tell, record and dump.
    fn attach_flight_recorder(&self, rank: usize, recorder: Arc<FlightRecorder>) {
        let _ = (rank, recorder);
    }
}

struct Board {
    slots: Vec<Option<Message>>,
    arrived: usize,
    generation: u64,
    published: Arc<[Message]>,
    /// The round-before-last's slab, kept for recycling: once every rank
    /// has deposited round `g+1` (a precondition of publishing it), no
    /// rank can still hold a reference to round `g-1`'s board, so its
    /// slab is uniquely owned again and can be overwritten in place.
    spare: Option<Arc<[Message]>>,
    /// Per-rank split-phase flag: `true` between a rank's begin and its
    /// complete (or abandon). Rejects double-starts, and caps the board
    /// at one outstanding round per rank — which is what guarantees
    /// `published` still holds round `g` when rank `r` completes `g`
    /// (no rank can deposit `g+1` before completing `g`).
    started: Vec<bool>,
    poisoned: bool,
    /// The rank whose failure poisoned the board, when the aborter
    /// identified itself ([`Transport::abort_from`]); `None` for an
    /// anonymous [`Transport::abort`]. First attribution wins.
    poisoned_by: Option<usize>,
}

/// In-process transport for one OS thread per rank.
pub struct LocalTransport {
    n: usize,
    epoch: u64,
    board: Mutex<Board>,
    cv: Condvar,
    /// Per-rank wire counters (payload account only — there is no
    /// socket, so the wire-byte account stays zero). Indexed by rank;
    /// lock-free, so bumps never touch the board mutex.
    obs: Vec<ObsCounters>,
    /// Guards the per-rank abort-counter bump so repeated aborts (the
    /// elastic teardown path aborts defensively) count once, matching
    /// the one poisoning they all describe.
    abort_counted: AtomicBool,
}

impl LocalTransport {
    /// Transport for `n` ranks.
    pub fn new(n: usize) -> Self {
        Self::new_at_epoch(n, 0)
    }

    /// Transport for `n` ranks formed at membership epoch `epoch` — the
    /// elastic recovery path builds one of these per re-formation.
    pub fn new_at_epoch(n: usize, epoch: u64) -> Self {
        LocalTransport {
            n,
            epoch,
            board: Mutex::new(Board {
                slots: (0..n).map(|_| None).collect(),
                arrived: 0,
                generation: 0,
                published: Vec::new().into(),
                spare: None,
                started: vec![false; n],
                poisoned: false,
                poisoned_by: None,
            }),
            cv: Condvar::new(),
            obs: (0..n).map(|_| ObsCounters::new()).collect(),
            abort_counted: AtomicBool::new(false),
        }
    }

    /// Deposit rank `rank`'s contribution into the current round without
    /// charging a collective-round counter — shared by both collective
    /// kinds (which charge their own round) and the rsag shard round
    /// (an internal hop, not a round of its own).
    fn begin_inner(&self, rank: usize, msg: Message) -> Result<RoundToken> {
        if rank >= self.n {
            return Err(Error::invalid(format!(
                "rank {rank} out of range (n = {})",
                self.n
            )));
        }
        let payload = msg.payload_bytes();
        let mut b = self.board.lock().unwrap();
        loop {
            if b.poisoned {
                return Err(poison_error(b.poisoned_by, b.generation));
            }
            if b.started[rank] {
                if b.slots[rank].is_some() {
                    // a real invariant error in every build profile — a
                    // silent overwrite here would corrupt a peer's board
                    // in release mode
                    return Err(Error::invariant(format!(
                        "rank {rank} double-deposited in round {}",
                        b.generation
                    )));
                }
                return Err(Error::invariant(format!(
                    "rank {rank} double-started a split-phase round (round {} \
                     is still in flight — finish or drop it first)",
                    b.generation
                )));
            }
            if b.slots[rank].is_none() {
                break;
            }
            // only reachable after an abandon: our previous deposit is
            // still waiting on slower peers, so the next round isn't
            // open yet — wait for the publish
            b = self.cv.wait(b).unwrap();
        }
        let my_gen = b.generation;
        b.slots[rank] = Some(msg);
        b.started[rank] = true;
        b.arrived += 1;
        if b.arrived == self.n {
            // last arrival: publish the board, open the next round
            let board = &mut *b;
            let recycled = board.spare.take().and_then(|mut slab| {
                if slab.len() == board.slots.len() && Arc::get_mut(&mut slab).is_some() {
                    Some(slab)
                } else {
                    None // a caller retained an old board; fall back
                }
            });
            let new_board: Arc<[Message]> = match recycled {
                Some(mut slab) => {
                    let dst = Arc::get_mut(&mut slab).expect("uniqueness checked above");
                    for (d, s) in dst.iter_mut().zip(board.slots.iter_mut()) {
                        *d = s.take().expect("all slots deposited");
                    }
                    slab
                }
                None => board
                    .slots
                    .iter_mut()
                    .map(|s| s.take().expect("all slots deposited"))
                    .collect(),
            };
            board.spare = Some(std::mem::replace(&mut board.published, new_board));
            board.arrived = 0;
            board.generation = board.generation.wrapping_add(1);
            self.cv.notify_all();
        }
        drop(b);
        self.obs[rank].payload_tx(payload);
        Ok(RoundToken::deferred(my_gen))
    }
}

impl Transport for LocalTransport {
    fn n_ranks(&self) -> usize {
        self.n
    }

    fn allgather(&self, rank: usize, msg: Message) -> Result<Arc<[Message]>> {
        // the blocking round is just the split phases back to back, so
        // both forms share every invariant check and the recycle path
        let token = self.allgather_begin(rank, msg)?;
        self.allgather_complete(rank, token)
    }

    fn allgather_begin(&self, rank: usize, msg: Message) -> Result<RoundToken> {
        let token = self.begin_inner(rank, msg)?;
        self.obs[rank].round(crate::cluster::CollectiveKind::Allgather);
        Ok(token)
    }

    fn allgather_complete(&self, rank: usize, token: RoundToken) -> Result<Arc<[Message]>> {
        if rank >= self.n {
            return Err(Error::invalid(format!(
                "rank {rank} out of range (n = {})",
                self.n
            )));
        }
        let my_gen = token.generation();
        let mut b = self.board.lock().unwrap();
        if !b.started[rank] {
            return Err(Error::invariant(format!(
                "rank {rank} completing a round it never started"
            )));
        }
        while b.generation == my_gen && !b.poisoned {
            b = self.cv.wait(b).unwrap();
        }
        b.started[rank] = false;
        if b.poisoned {
            return Err(poison_error(b.poisoned_by, b.generation));
        }
        if b.generation != my_gen.wrapping_add(1) {
            // unreachable while the one-outstanding-round-per-rank
            // invariant holds (no rank can deposit g+1 before completing
            // g); a typed error beats returning the wrong round's board
            return Err(Error::invariant(format!(
                "rank {rank}'s round {my_gen} board was already recycled \
                 (board is at round {}) — rounds overlapped illegally",
                b.generation
            )));
        }
        // every rank shares the one published slab — a refcount bump, not
        // a copy; the modeled wire cost is charged by the collectives
        let board = b.published.clone();
        drop(b);
        // receive account: everything on the board but our own entry —
        // the `(n-1)·B` fan-in the recv-bytes predictions are stated in
        let rx: usize = board
            .iter()
            .enumerate()
            .filter(|(r, _)| *r != rank)
            .map(|(_, m)| m.payload_bytes())
            .sum();
        self.obs[rank].payload_rx(rx);
        Ok(board)
    }

    fn allgather_abandon(&self, rank: usize, token: RoundToken) {
        let _ = token;
        if rank >= self.n {
            return;
        }
        // the deposit from begin stands (peers need it to publish the
        // round); only the local in-flight flag is released, so a later
        // begin re-enters once this round publishes
        let mut b = self.board.lock().unwrap();
        b.started[rank] = false;
    }

    fn rsag_complete(
        &self,
        rank: usize,
        token: RoundToken,
        shards: &mut FloatBufPool,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        // Native reduce-scatter → all-gather as two board rounds: the
        // in-flight contribution round publishes the full board
        // zero-copy (Arc bumps, not element copies), each rank reduces
        // ONLY its own shard — O(len) compute per rank instead of the
        // default's O(n·len) — and a second board round gathers the n
        // reduced shards. Both rounds ride the recycled-slab path and
        // the shard buffer comes from the pool, so steady-state rounds
        // allocate nothing (`rust/tests/alloc_regression.rs`).
        let board = self.allgather_complete(rank, token)?;
        let mut reduced_len: Result<usize> = Ok(0);
        let shard = shards.fill(|buf| {
            reduced_len = reduce_own_shard_into(&board, rank, buf);
        });
        let len = reduced_len?;
        // release our board clone before depositing the shard round so
        // the contribution slab recycles on schedule
        drop(board);
        // the shard gather is an internal hop of the rsag round, not a
        // collective round of its own — skip the round counter
        let shard_token = self.begin_inner(rank, Message::Floats(shard))?;
        let shard_board = self.allgather_complete(rank, shard_token)?;
        assemble_shards_into(&shard_board, len, out)
    }

    fn rsag_begin(&self, rank: usize, contribution: Arc<Vec<f32>>) -> Result<RoundToken> {
        let token = self.begin_inner(rank, Message::Floats(contribution))?;
        self.obs[rank].round(crate::cluster::CollectiveKind::Rsag);
        Ok(token)
    }

    fn rsag_sparse_begin(
        &self,
        rank: usize,
        contribution: Arc<SparseVec>,
        round: SparseRound,
    ) -> Result<RoundToken> {
        // one zero-copy board round plus the default complete's full
        // canonical replay IS the native sparse rsag here: the board
        // fan-out is Arc bumps, so there is no shard hop to save, and
        // the replay derives every rank's reduced entries and residual
        // in one pass. Only the round counter needs charging.
        let _ = round;
        let token = self.begin_inner(rank, Message::Sparse(contribution))?;
        self.obs[rank].round(crate::cluster::CollectiveKind::Rsag);
        Ok(token)
    }

    fn abort(&self) {
        self.poison(None);
    }

    fn abort_from(&self, rank: usize) {
        self.poison(Some(rank));
    }

    fn epoch(&self) -> u64 {
        self.epoch
    }

    fn counters(&self, rank: usize) -> Option<&ObsCounters> {
        self.obs.get(rank)
    }
}

impl LocalTransport {
    fn poison(&self, by: Option<usize>) {
        let mut b = self.board.lock().unwrap();
        b.poisoned = true;
        if b.poisoned_by.is_none() {
            b.poisoned_by = by;
        }
        self.cv.notify_all();
        drop(b);
        // every rank observes the poisoning at its next rendezvous; the
        // counter describes the one poisoning, however many defensive
        // abort calls repeat it
        if !self.abort_counted.swap(true, Relaxed) {
            for c in &self.obs {
                c.abort();
            }
        }
    }
}

/// Typed poison error for an in-process board: an attributed poisoning
/// is [`Error::PeerLost`] naming the failed rank, an anonymous one is
/// [`Error::Poisoned`]; both carry the round generation the survivors
/// observed the poisoning at. Shared by [`LocalTransport`] and the
/// in-process ring.
pub(crate) fn poison_error(by: Option<usize>, generation: u64) -> Error {
    match by {
        Some(rank) => Error::peer_lost(rank, generation),
        None => Error::poisoned(generation),
    }
}

/// Rotating pool of reusable `Arc<Vec<f32>>` send buffers for
/// [`Message::Floats`] contributions.
///
/// A buffer handed out in round `g` is shared with the peers (who drop
/// their board clones before depositing round `g+1`) and with
/// [`LocalTransport`] itself, which keeps the round-`g` slab alive as
/// its recycling spare until the publish of round `g+2`. The buffer is
/// therefore guaranteed uniquely owned again only at its owner's round
/// `g+3` send — exactly the reuse distance the THREE-slot rotation
/// provides (a 2-slot pool would find the transport's spare still
/// holding the Arc and fall back to allocating every round). If a
/// caller retains a board even longer the pool transparently falls back
/// to a fresh buffer, so reuse is an optimization, never a correctness
/// assumption.
pub struct FloatBufPool {
    bufs: [Arc<Vec<f32>>; 3],
    next: usize,
}

impl FloatBufPool {
    /// Empty pool; buffers grow to the working-set size on first use.
    pub fn new() -> Self {
        FloatBufPool {
            bufs: [
                Arc::new(Vec::new()),
                Arc::new(Vec::new()),
                Arc::new(Vec::new()),
            ],
            next: 0,
        }
    }

    /// Hand out a shareable buffer, cleared and then filled by `fill`.
    pub fn fill(&mut self, fill: impl FnOnce(&mut Vec<f32>)) -> Arc<Vec<f32>> {
        let idx = self.next;
        self.next = (idx + 1) % self.bufs.len();
        let slot = &mut self.bufs[idx];
        if Arc::get_mut(slot).is_none() {
            // a peer still holds the handle from this slot's last round
            // (only possible outside the steady state, e.g. a retained
            // board) — fall back to a fresh buffer
            *slot = Arc::new(Vec::new());
        }
        let buf = Arc::get_mut(slot).expect("slot is uniquely owned here");
        buf.clear();
        fill(buf);
        Arc::clone(slot)
    }
}

impl Default for FloatBufPool {
    fn default() -> Self {
        Self::new()
    }
}

/// Rotating pool of reusable `Arc<SparseVec>` send buffers for
/// [`Message::Sparse`] contributions — the entry-list twin of
/// [`FloatBufPool`], with the identical three-slot reuse-distance
/// argument and the identical fall-back-to-fresh guarantee when a
/// caller retains a board longer than the steady state.
pub struct SparseBufPool {
    bufs: [Arc<SparseVec>; 3],
    next: usize,
}

impl SparseBufPool {
    /// Empty pool; buffers grow to the working-set size on first use.
    pub fn new() -> Self {
        SparseBufPool {
            bufs: [
                Arc::new(SparseVec::new()),
                Arc::new(SparseVec::new()),
                Arc::new(SparseVec::new()),
            ],
            next: 0,
        }
    }

    /// Hand out a shareable entry list, cleared and then filled by
    /// `fill`.
    pub fn fill(&mut self, fill: impl FnOnce(&mut SparseVec)) -> Arc<SparseVec> {
        let idx = self.next;
        self.next = (idx + 1) % self.bufs.len();
        let slot = &mut self.bufs[idx];
        if Arc::get_mut(slot).is_none() {
            // a peer still holds the handle from this slot's last round
            // — fall back to a fresh buffer (reuse is an optimization,
            // never a correctness assumption)
            *slot = Arc::new(SparseVec::new());
        }
        let buf = Arc::get_mut(slot).expect("slot is uniquely owned here");
        buf.clear();
        fill(buf);
        Arc::clone(slot)
    }
}

impl Default for SparseBufPool {
    fn default() -> Self {
        Self::new()
    }
}

/// One rank's handle onto a transport: typed all-gather helpers that
/// unwrap the [`Message`] envelope (an envelope mismatch means workers
/// diverged in control flow — an invariant error, never silent).
pub struct Endpoint<'a> {
    /// This rank.
    pub rank: usize,
    tp: &'a dyn Transport,
}

impl<'a> Endpoint<'a> {
    /// Handle for `rank` over `tp`.
    pub fn new(rank: usize, tp: &'a dyn Transport) -> Self {
        Endpoint { rank, tp }
    }

    /// Cluster size.
    pub fn n_ranks(&self) -> usize {
        self.tp.n_ranks()
    }

    /// Underlying transport (for `abort`).
    pub fn transport(&self) -> &dyn Transport {
        self.tp
    }

    /// Raw all-gather: contribute `msg`, receive the shared rank-indexed
    /// board. The allocation-free primitive the per-rank collectives
    /// ([`crate::collectives::ranked`]) build on.
    pub fn allgather(&self, msg: Message) -> Result<Arc<[Message]>> {
        self.tp.allgather(self.rank, msg)
    }

    /// Split-phase all-gather: the contribution is deposited / put on
    /// the wire before this returns; `finish()` on the returned handle
    /// blocks for the board. The pipelined engines run iteration t+1's
    /// compute between the two halves.
    pub fn allgather_start(&self, msg: Message) -> Result<PendingRound<'a>> {
        PendingRound::start(self.tp, self.rank, msg)
    }

    /// Reduce-scatter → all-gather: contribute a dense f32 vector,
    /// receive the canonically-ordered SUM over ranks in `out`
    /// ([`Transport::reduce_scatter_allgather`]). `shards` backs the
    /// reduced-shard buffers so steady-state rounds allocate nothing.
    pub fn reduce_scatter_allgather(
        &self,
        contribution: Arc<Vec<f32>>,
        shards: &mut FloatBufPool,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        self.tp
            .reduce_scatter_allgather(self.rank, contribution, shards, out)
    }

    /// Split-phase reduce-scatter → all-gather: the contribution is in
    /// flight before this returns; `finish()` on the returned handle
    /// blocks for the reduced vector. Shares the one-outstanding-round
    /// budget with [`Endpoint::allgather_start`].
    pub fn rsag_start(&self, contribution: Arc<Vec<f32>>) -> Result<PendingReduce<'a>> {
        PendingReduce::start(self.tp, self.rank, contribution)
    }

    /// Truly sparse rsag: contribute a sorted `(position, value)` entry
    /// list, receive the canonically reduced entries in `out` and this
    /// rank's re-selection residual in `residual`
    /// ([`Transport::rsag_sparse`]).
    pub fn rsag_sparse(
        &self,
        contribution: Arc<SparseVec>,
        round: SparseRound,
        scratch: &mut SparseReduceScratch,
        out: &mut SparseVec,
        residual: &mut SparseVec,
    ) -> Result<()> {
        self.tp
            .rsag_sparse(self.rank, contribution, round, scratch, out, residual)
    }

    /// Split-phase truly sparse rsag: the entry list is in flight
    /// before this returns; `finish()` on the returned handle blocks
    /// for the reduced entries and residual. Shares the
    /// one-outstanding-round budget with every other collective start.
    pub fn rsag_sparse_start(
        &self,
        contribution: Arc<SparseVec>,
        round: SparseRound,
    ) -> Result<PendingSparseReduce<'a>> {
        PendingSparseReduce::start(self.tp, self.rank, contribution, round)
    }

    /// All-gather per-rank selections (metadata + payload in one round).
    /// The returned entries share the senders' buffers.
    pub fn allgather_select(&self, mine: Arc<SelectOutput>) -> Result<Vec<Arc<SelectOutput>>> {
        let board = self.tp.allgather(self.rank, Message::Selection(mine))?;
        board
            .iter()
            .map(|m| match m {
                Message::Selection(s) => Ok(Arc::clone(s)),
                other => Err(envelope_mismatch("Selection", other)),
            })
            .collect()
    }

    /// All-gather dense f32 payloads (all-reduce contributions). The
    /// returned entries share the senders' buffers.
    pub fn allgather_floats(&self, mine: Arc<Vec<f32>>) -> Result<Vec<Arc<Vec<f32>>>> {
        let board = self.tp.allgather(self.rank, Message::Floats(mine))?;
        board
            .iter()
            .map(|m| match m {
                Message::Floats(v) => Ok(Arc::clone(v)),
                other => Err(envelope_mismatch("Floats", other)),
            })
            .collect()
    }

    /// All-gather one f64 per rank (timings, norms).
    pub fn allgather_f64(&self, mine: f64) -> Result<Vec<f64>> {
        self.allgather_f64_fold(mine, Vec::with_capacity(self.n_ranks()), |mut acc, x| {
            acc.push(x);
            acc
        })
    }

    /// All-gather one f64 per rank and fold the rank-ordered values
    /// without materializing them — the allocation-free form for sums
    /// and maxima on the hot path.
    pub fn allgather_f64_fold<T>(
        &self,
        mine: f64,
        init: T,
        mut f: impl FnMut(T, f64) -> T,
    ) -> Result<T> {
        let board = self.tp.allgather(self.rank, Message::Scalar(mine))?;
        let mut acc = init;
        for m in board.iter() {
            match m {
                Message::Scalar(x) => acc = f(acc, *x),
                other => return Err(envelope_mismatch("Scalar", other)),
            }
        }
        Ok(acc)
    }

    /// Barrier.
    pub fn barrier(&self) -> Result<()> {
        self.tp.barrier(self.rank)
    }
}

/// Publish a completed round's slot board as an `Arc<[Message]>` slab,
/// recycling the previous round's slab when the caller has dropped its
/// clone (the per-rank twin of [`LocalTransport`]'s double-buffered
/// rotation, shared by both ring transports): `last` holds our clone of
/// the previously published board; if it is uniquely owned again it is
/// refilled in place, otherwise a fresh slab is allocated. Every slot
/// must be `Some` (the round is complete); slots are left `None` for
/// the next round.
pub(crate) fn publish_recycled(
    slots: &mut [Option<Message>],
    last: &mut Option<Arc<[Message]>>,
) -> Arc<[Message]> {
    let n = slots.len();
    let recycled = last.take().and_then(|mut slab| {
        if slab.len() == n && Arc::get_mut(&mut slab).is_some() {
            Some(slab)
        } else {
            None // a caller retained an old board; fall back
        }
    });
    let board: Arc<[Message]> = match recycled {
        Some(mut slab) => {
            let dst = Arc::get_mut(&mut slab).expect("uniqueness checked above");
            for (d, s) in dst.iter_mut().zip(slots.iter_mut()) {
                *d = s.take().expect("completed round fills every slot");
            }
            slab
        }
        None => slots
            .iter_mut()
            .map(|s| s.take().expect("completed round fills every slot"))
            .collect(),
    };
    *last = Some(Arc::clone(&board));
    board
}

/// RAII guard for worker threads: if the holding thread unwinds (a
/// panic, not an `Err`), the transport is poisoned so peer ranks error
/// out of their rendezvous instead of blocking forever. The explicit
/// `Err` paths call [`Transport::abort`] themselves; this covers the
/// path no `if out.is_err()` check can.
pub(crate) struct AbortOnPanic<'a>(pub &'a dyn Transport);

impl Drop for AbortOnPanic<'_> {
    fn drop(&mut self) {
        if std::thread::panicking() {
            self.0.abort();
        }
    }
}

pub(crate) fn envelope_mismatch(want: &str, got: &Message) -> Error {
    let got = match got {
        Message::Selection(_) => "Selection",
        Message::Floats(_) => "Floats",
        Message::Scalar(_) => "Scalar",
        Message::Sparse(_) => "Sparse",
    };
    Error::invariant(format!(
        "transport envelope mismatch: expected {want}, got {got} — workers diverged"
    ))
}

/// Validate that every board entry is a [`Message::Floats`] of one
/// common length and return that length (0 for an empty board). The
/// shared precondition of every reduce-scatter reduction helper.
pub(crate) fn floats_board_len(board: &[Message]) -> Result<usize> {
    let mut len = None;
    for m in board.iter() {
        match m {
            Message::Floats(v) => match len {
                None => len = Some(v.len()),
                Some(l) if l == v.len() => {}
                Some(l) => {
                    return Err(Error::invariant(format!(
                        "reduce-scatter contributions disagree on length \
                         ({l} vs {}) — workers diverged",
                        v.len()
                    )))
                }
            },
            other => return Err(envelope_mismatch("Floats", other)),
        }
    }
    Ok(len.unwrap_or(0))
}

/// Reduce a full contribution board into the canonically-ordered SUM —
/// the fallback reduction behind the default
/// [`Transport::rsag_complete`] and the hub side of the TCP star.
pub(crate) fn rsag_reduce_board_into(board: &[Message], out: &mut Vec<f32>) -> Result<()> {
    let len = floats_board_len(board)?;
    reduce_contributions_rsag_with(
        board.len(),
        len,
        |r| match &board[r] {
            Message::Floats(v) => &v[..],
            _ => unreachable!("validated by floats_board_len"),
        },
        out,
    );
    Ok(())
}

/// Replay the canonical sparse rsag reduce on a full contribution
/// board: validate every entry is a [`Message::Sparse`] inside the
/// round's union bounds, reduce all shards in canonical order with the
/// round's re-selection cap, keep the discards attributed to `rank` as
/// its residual, and canonicalize that residual to a sorted entry
/// list. The fallback reduction behind the default
/// [`Transport::rsag_sparse_complete`], the whole reduction on
/// [`LocalTransport`] (where the board fan-out is free), and the hub
/// side of the TCP star.
pub(crate) fn rsag_sparse_reduce_board_into(
    board: &[Message],
    rank: usize,
    round: SparseRound,
    scratch: &mut SparseReduceScratch,
    out: &mut SparseVec,
    residual: &mut SparseVec,
) -> Result<()> {
    for (r, m) in board.iter().enumerate() {
        match m {
            Message::Sparse(s) => {
                if let Some(&last) = s.idx.last() {
                    if last as usize >= round.union_len {
                        return Err(Error::invariant(format!(
                            "rank {r}'s sparse contribution indexes position {last}, \
                             union length is {} — workers diverged",
                            round.union_len
                        )));
                    }
                }
            }
            other => return Err(envelope_mismatch("Sparse", other)),
        }
    }
    residual.clear();
    reduce_sparse_contributions_with(
        board.len(),
        round.union_len,
        |r| match &board[r] {
            Message::Sparse(s) => (&s.idx[..], &s.val[..]),
            _ => unreachable!("validated above"),
        },
        round.shard_k,
        scratch,
        out,
        |owner, pos, v| {
            if owner == rank {
                residual.push_entry(pos, v);
            }
        },
    );
    canonicalize_residual(residual, scratch);
    Ok(())
}

/// Reduce shard `rank` of a full contribution board into `buf` in the
/// canonical [`rsag_rank_order`], returning the board's full vector
/// length. `buf` is cleared and sized to the shard; the per-rank
/// reduce compute is O(len) instead of the full board's O(n·len).
pub(crate) fn reduce_own_shard_into(
    board: &[Message],
    rank: usize,
    buf: &mut Vec<f32>,
) -> Result<usize> {
    let n = board.len();
    let len = floats_board_len(board)?;
    let (s, e) = shard_bounds(len, n, rank);
    buf.clear();
    buf.resize(e - s, 0.0);
    for r in rsag_rank_order(n, rank) {
        let vals = match &board[r] {
            Message::Floats(v) => &v[s..e],
            _ => unreachable!("validated by floats_board_len"),
        };
        for (o, &x) in buf.iter_mut().zip(vals.iter()) {
            *o += x;
        }
    }
    Ok(len)
}

/// Assemble a board of n reduced shards (rank i's entry carries shard
/// i, [`shard_bounds`]-sized for a `len`-long vector) into the full
/// reduced vector. The shards partition the index space, so every
/// element of `out` is written.
pub(crate) fn assemble_shards_into(
    shard_board: &[Message],
    len: usize,
    out: &mut Vec<f32>,
) -> Result<()> {
    let n = shard_board.len();
    out.clear();
    out.resize(len, 0.0);
    for (i, m) in shard_board.iter().enumerate() {
        let (s, e) = shard_bounds(len, n, i);
        match m {
            Message::Floats(v) => {
                if v.len() != e - s {
                    return Err(Error::invariant(format!(
                        "rank {i}'s reduced shard carries {} values, want {} \
                         — shard layouts diverged",
                        v.len(),
                        e - s
                    )));
                }
                out[s..e].copy_from_slice(v);
            }
            other => return Err(envelope_mismatch("Floats", other)),
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn single_rank_allgather_is_identity() {
        let tp = LocalTransport::new(1);
        let ep = Endpoint::new(0, &tp);
        let got = ep.allgather_f64(2.5).unwrap();
        assert_eq!(got, vec![2.5]);
        // rounds are reusable
        let got = ep.allgather_f64(3.5).unwrap();
        assert_eq!(got, vec![3.5]);
    }

    #[test]
    fn multi_rank_allgather_is_rank_indexed_over_rounds() {
        let n = 4;
        let rounds = 25;
        let tp = Arc::new(LocalTransport::new(n));
        let mut handles = Vec::new();
        for rank in 0..n {
            let tp = tp.clone();
            handles.push(std::thread::spawn(move || {
                let ep = Endpoint::new(rank, tp.as_ref());
                for round in 0..rounds {
                    let mine = (rank * 1000 + round) as f64;
                    let got = ep.allgather_f64(mine).unwrap();
                    let want: Vec<f64> = (0..n).map(|r| (r * 1000 + round) as f64).collect();
                    assert_eq!(got, want, "rank {rank} round {round}");
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn selections_roundtrip() {
        let n = 2;
        let tp = Arc::new(LocalTransport::new(n));
        let mk = |r: usize| SelectOutput {
            idx: vec![r as u32, 10 + r as u32],
            val: vec![r as f32, -(r as f32)],
        };
        let mut handles = Vec::new();
        for rank in 0..n {
            let tp = tp.clone();
            let mine = Arc::new(mk(rank));
            handles.push(std::thread::spawn(move || {
                let ep = Endpoint::new(rank, tp.as_ref());
                ep.allgather_select(mine).unwrap()
            }));
        }
        for h in handles {
            let outs = h.join().unwrap();
            assert_eq!(outs.len(), n);
            for (r, o) in outs.iter().enumerate() {
                assert_eq!(o.as_ref(), &mk(r));
            }
        }
    }

    #[test]
    fn ranks_share_one_board_slab() {
        // the O(n) fan-out claim at its root: both ranks' boards are the
        // SAME allocation, and a shared payload is the sender's buffer
        let n = 2;
        let tp = Arc::new(LocalTransport::new(n));
        let payload = Arc::new(vec![1.0f32, 2.0]);
        let sent = Arc::clone(&payload);
        let tp1 = tp.clone();
        let h = std::thread::spawn(move || tp1.allgather(1, Message::Floats(sent)).unwrap());
        let board0 = tp.allgather(0, Message::Floats(Arc::new(vec![0.5]))).unwrap();
        let board1 = h.join().unwrap();
        assert!(
            Arc::ptr_eq(&board0, &board1),
            "ranks must share one published slab"
        );
        match &board0[1] {
            Message::Floats(v) => {
                assert!(Arc::ptr_eq(v, &payload), "payload must not be copied")
            }
            other => panic!("wrong envelope {other:?}"),
        }
    }

    #[test]
    fn double_deposit_is_a_typed_error_in_all_builds() {
        let tp = Arc::new(LocalTransport::new(2));
        let tp2 = tp.clone();
        // rank 0 deposits and blocks waiting for rank 1 ...
        let blocked = std::thread::spawn(move || tp2.allgather(0, Message::Scalar(1.0)));
        std::thread::sleep(Duration::from_millis(30));
        // ... and a buggy second caller for rank 0 must get a typed
        // error, not silently overwrite the slot (this used to be a
        // debug_assert — release builds corrupted the board)
        let err = tp
            .allgather(0, Message::Scalar(2.0))
            .unwrap_err()
            .to_string();
        assert!(err.contains("double-deposited"), "{err}");
        tp.abort();
        assert!(blocked.join().unwrap().is_err());
    }

    #[test]
    fn abort_unblocks_waiters_with_error() {
        let tp = Arc::new(LocalTransport::new(2));
        let tp2 = tp.clone();
        let waiter = std::thread::spawn(move || {
            let ep = Endpoint::new(0, tp2.as_ref());
            ep.allgather_f64(1.0)
        });
        // give the waiter time to block, then poison instead of joining
        std::thread::sleep(Duration::from_millis(20));
        tp.abort();
        let res = waiter.join().unwrap();
        assert!(res.is_err(), "poisoned transport must error, not hang");
        // later calls fail fast
        let ep = Endpoint::new(1, tp.as_ref());
        assert!(ep.allgather_f64(2.0).is_err());
    }

    #[test]
    fn out_of_range_rank_rejected() {
        let tp = LocalTransport::new(2);
        let ep = Endpoint::new(5, &tp);
        assert!(ep.allgather_f64(0.0).is_err());
    }

    #[test]
    fn float_buf_pool_reuses_released_buffers() {
        let mut pool = FloatBufPool::new();
        let a = pool.fill(|b| b.extend_from_slice(&[1.0, 2.0]));
        assert_eq!(*a, vec![1.0, 2.0]);
        let a_ptr = Arc::as_ptr(&a);
        drop(a);
        // cycle through the rotation; the released slot must come back
        let mut seen = false;
        for i in 0..6 {
            let b = pool.fill(|b| b.push(i as f32));
            seen |= Arc::as_ptr(&b) == a_ptr;
            assert_eq!(*b, vec![i as f32], "cleared before refill");
        }
        assert!(seen, "released buffer must be recycled");
        // a retained buffer is never clobbered
        let held = pool.fill(|b| b.push(7.0));
        for i in 0..6 {
            let b = pool.fill(|b| b.push(i as f32));
            assert!(!Arc::ptr_eq(&b, &held), "live handle must not be reused");
        }
        assert_eq!(*held, vec![7.0]);
    }

    #[test]
    fn split_phase_rounds_match_blocking_rounds() {
        let n = 3;
        let rounds = 20;
        let tp = Arc::new(LocalTransport::new(n));
        let mut handles = Vec::new();
        for rank in 0..n {
            let tp = tp.clone();
            handles.push(std::thread::spawn(move || {
                let ep = Endpoint::new(rank, tp.as_ref());
                for round in 0..rounds {
                    let mine = (rank * 1000 + round) as f64;
                    let want: Vec<f64> =
                        (0..n).map(|r| (r * 1000 + round) as f64).collect();
                    let got: Vec<f64> = if round % 2 == 0 {
                        // split phase, with rank-local work in the gap
                        let pending =
                            ep.allgather_start(Message::Scalar(mine)).unwrap();
                        assert_eq!(pending.rank(), rank);
                        let board = pending.finish().unwrap();
                        board
                            .iter()
                            .map(|m| match m {
                                Message::Scalar(x) => *x,
                                other => panic!("wrong envelope {other:?}"),
                            })
                            .collect()
                    } else {
                        // blocking rounds interleave with split-phase ones
                        ep.allgather_f64(mine).unwrap()
                    };
                    assert_eq!(got, want, "rank {rank} round {round}");
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn double_start_is_rejected_and_round_still_lands() {
        let tp = LocalTransport::new(1);
        let dynamic: &dyn Transport = &tp;
        let pending = dynamic.allgather_start(0, Message::Scalar(1.0)).unwrap();
        let err = dynamic
            .allgather_start(0, Message::Scalar(2.0))
            .err()
            .expect("second start must be rejected")
            .to_string();
        assert!(err.contains("double-started"), "{err}");
        let board = pending.finish().unwrap();
        assert_eq!(&board[..], &[Message::Scalar(1.0)]);
        // the transport recovers fully
        let board = dynamic.allgather(0, Message::Scalar(3.0)).unwrap();
        assert_eq!(&board[..], &[Message::Scalar(3.0)]);
    }

    #[test]
    fn dropped_pending_round_does_not_wedge_peers() {
        let n = 2;
        let rounds = 4;
        let tp = Arc::new(LocalTransport::new(n));
        let tp1 = tp.clone();
        let peer = std::thread::spawn(move || {
            let ep = Endpoint::new(1, tp1.as_ref());
            for round in 0..rounds {
                // the peer must see rank 0's deposit in EVERY round,
                // including the one rank 0 abandoned
                let got = ep.allgather_f64((1000 + round) as f64).unwrap();
                assert_eq!(got[0], round as f64, "round {round}");
            }
        });
        let ep = Endpoint::new(0, tp.as_ref());
        for round in 0..rounds {
            if round == 1 {
                let pending = ep.allgather_start(Message::Scalar(round as f64)).unwrap();
                drop(pending); // walk away without finishing
            } else {
                let got = ep.allgather_f64(round as f64).unwrap();
                assert_eq!(got[1], (1000 + round) as f64);
            }
        }
        peer.join().unwrap();
    }

    #[test]
    fn abort_between_start_and_finish_poisons_the_finish() {
        let tp = Arc::new(LocalTransport::new(2));
        let pending = (tp.as_ref() as &dyn Transport)
            .allgather_start(0, Message::Scalar(1.0))
            .unwrap();
        tp.abort();
        assert!(pending.finish().is_err(), "poisoned finish must error");
    }

    #[test]
    fn default_split_phase_emulation_is_correct() {
        // a Transport that overrides nothing still gets a working (if
        // overlap-free) split phase via the eager default
        struct Eager(LocalTransport);
        impl Transport for Eager {
            fn n_ranks(&self) -> usize {
                self.0.n_ranks()
            }
            fn allgather(&self, rank: usize, msg: Message) -> Result<Arc<[Message]>> {
                self.0.allgather(rank, msg)
            }
            fn abort(&self) {
                self.0.abort()
            }
        }
        let tp = Eager(LocalTransport::new(1));
        let dynamic: &dyn Transport = &tp;
        let pending = dynamic.allgather_start(0, Message::Scalar(7.5)).unwrap();
        let board = pending.finish().unwrap();
        assert_eq!(&board[..], &[Message::Scalar(7.5)]);
    }

    #[test]
    fn panicking_worker_poisons_transport_via_guard() {
        let tp = Arc::new(LocalTransport::new(2));
        let tp2 = tp.clone();
        let panicker = std::thread::spawn(move || {
            let _guard = AbortOnPanic(tp2.as_ref() as &dyn Transport);
            panic!("worker died without returning an Err");
        });
        assert!(panicker.join().is_err());
        // the surviving rank must error out, not block forever
        let ep = Endpoint::new(0, tp.as_ref());
        assert!(ep.allgather_f64(0.0).is_err());
    }

    /// Magnitude data that makes the reduction order observable in f32:
    /// summing a rotation of {1e8, 1, -1e8} absorbs or keeps the 1
    /// depending on which value arrives first.
    fn order_probe(rank: usize, round: usize, len: usize) -> Vec<f32> {
        const VALS: [f32; 3] = [1.0e8, 1.0, -1.0e8];
        (0..len).map(|i| VALS[(rank + i + round) % 3]).collect()
    }

    #[test]
    fn rsag_lands_the_canonical_shard_order_over_rounds() {
        let n = 3;
        let len = 10;
        let rounds = 12;
        let tp = Arc::new(LocalTransport::new(n));
        let mut handles = Vec::new();
        for rank in 0..n {
            let tp = tp.clone();
            handles.push(std::thread::spawn(move || {
                let ep = Endpoint::new(rank, tp.as_ref());
                let mut send = FloatBufPool::new();
                let mut shards = FloatBufPool::new();
                let mut out = Vec::new();
                for round in 0..rounds {
                    let mine =
                        send.fill(|b| b.extend_from_slice(&order_probe(rank, round, len)));
                    if round % 2 == 0 {
                        ep.reduce_scatter_allgather(mine, &mut shards, &mut out)
                            .unwrap();
                    } else {
                        // split phase interleaves with blocking rounds
                        let pending = ep.rsag_start(mine).unwrap();
                        assert_eq!(pending.rank(), rank);
                        pending.finish(&mut shards, &mut out).unwrap();
                    }
                    let parts: Vec<Vec<f32>> =
                        (0..n).map(|r| order_probe(r, round, len)).collect();
                    let mut want = Vec::new();
                    reduce_contributions_rsag_with(n, len, |r| &parts[r][..], &mut want);
                    let got: Vec<u32> = out.iter().map(|x| x.to_bits()).collect();
                    let want: Vec<u32> = want.iter().map(|x| x.to_bits()).collect();
                    assert_eq!(got, want, "rank {rank} round {round}");
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn dropped_pending_reduce_does_not_wedge_peers() {
        let n = 2;
        let rounds = 4;
        let len = 6;
        let tp = Arc::new(LocalTransport::new(n));
        let tp1 = tp.clone();
        let peer = std::thread::spawn(move || {
            let ep = Endpoint::new(1, tp1.as_ref());
            let mut shards = FloatBufPool::new();
            let mut out = Vec::new();
            for round in 0..rounds {
                let mine = Arc::new(vec![1.0f32; len]);
                ep.reduce_scatter_allgather(mine, &mut shards, &mut out)
                    .unwrap();
                // rank 0's contribution lands in EVERY round, including
                // the one rank 0 abandoned (the abandon drains both
                // phases, so the reduce completes on both sides)
                assert_eq!(out, vec![(round + 2) as f32; len], "round {round}");
            }
        });
        let ep = Endpoint::new(0, tp.as_ref());
        let mut shards = FloatBufPool::new();
        let mut out = Vec::new();
        for round in 0..rounds {
            let mine = Arc::new(vec![(round + 1) as f32; len]);
            if round == 1 {
                let pending = ep.rsag_start(mine).unwrap();
                drop(pending); // walk away without finishing
            } else {
                ep.reduce_scatter_allgather(mine, &mut shards, &mut out)
                    .unwrap();
                assert_eq!(out, vec![(round + 2) as f32; len]);
            }
        }
        peer.join().unwrap();
    }

    #[test]
    fn rsag_shares_the_one_outstanding_round_budget() {
        let tp = LocalTransport::new(1);
        let dynamic: &dyn Transport = &tp;
        let mut shards = FloatBufPool::new();
        let mut out = Vec::new();
        let pending = dynamic.rsag_start(0, Arc::new(vec![2.0f32, 3.0])).unwrap();
        // NEITHER collective kind may start while the reduce is in flight
        let err = dynamic
            .allgather_start(0, Message::Scalar(1.0))
            .err()
            .expect("mixed double start must be rejected")
            .to_string();
        assert!(err.contains("double-started"), "{err}");
        let err = dynamic
            .rsag_start(0, Arc::new(vec![0.0f32; 2]))
            .err()
            .expect("rsag double start must be rejected")
            .to_string();
        assert!(err.contains("double-started"), "{err}");
        pending.finish(&mut shards, &mut out).unwrap();
        assert_eq!(out, vec![2.0, 3.0]);
        // and the transport recovers fully
        dynamic
            .reduce_scatter_allgather(0, Arc::new(vec![4.0f32, 5.0]), &mut shards, &mut out)
            .unwrap();
        assert_eq!(out, vec![4.0, 5.0]);
    }

    #[test]
    fn abort_mid_reduce_poisons_the_finish() {
        let tp = Arc::new(LocalTransport::new(2));
        let pending = (tp.as_ref() as &dyn Transport)
            .rsag_start(0, Arc::new(vec![1.0f32; 4]))
            .unwrap();
        tp.abort();
        let mut shards = FloatBufPool::new();
        let mut out = Vec::new();
        assert!(
            pending.finish(&mut shards, &mut out).is_err(),
            "poisoned reduce must error, not hang"
        );
    }

    #[test]
    fn default_rsag_emulation_matches_the_native_reduce_bit_for_bit() {
        // a Transport that overrides nothing reduces the full board
        // locally in the same canonical order the native path uses, so
        // the sums are bit-identical (only the received volume differs)
        struct Eager(LocalTransport);
        impl Transport for Eager {
            fn n_ranks(&self) -> usize {
                self.0.n_ranks()
            }
            fn allgather(&self, rank: usize, msg: Message) -> Result<Arc<[Message]>> {
                self.0.allgather(rank, msg)
            }
            fn abort(&self) {
                self.0.abort()
            }
        }
        fn run(tp: Arc<dyn Transport>, n: usize, len: usize) -> Vec<u32> {
            let mut handles = Vec::new();
            for rank in 0..n {
                let tp = tp.clone();
                handles.push(std::thread::spawn(move || {
                    let mut shards = FloatBufPool::new();
                    let mut out = Vec::new();
                    tp.reduce_scatter_allgather(
                        rank,
                        Arc::new(order_probe(rank, 0, len)),
                        &mut shards,
                        &mut out,
                    )
                    .unwrap();
                    out.iter().map(|x| x.to_bits()).collect::<Vec<u32>>()
                }));
            }
            let outs: Vec<Vec<u32>> =
                handles.into_iter().map(|h| h.join().unwrap()).collect();
            for o in &outs[1..] {
                assert_eq!(o, &outs[0], "ranks must agree on the reduced vector");
            }
            outs.into_iter().next().unwrap()
        }
        let (n, len) = (3, 11);
        let native = run(Arc::new(LocalTransport::new(n)), n, len);
        let eager = run(Arc::new(Eager(LocalTransport::new(n))), n, len);
        assert_eq!(native, eager);
    }

    #[test]
    fn message_payload_bytes_match_model_units() {
        let sel = Message::Selection(Arc::new(SelectOutput {
            idx: vec![1, 2, 3],
            val: vec![0.0; 3],
        }));
        assert_eq!(sel.payload_bytes(), 3 * 8, "8 B per sparse entry");
        let floats = Message::Floats(Arc::new(vec![0.0f32; 5]));
        assert_eq!(floats.payload_bytes(), 5 * 4, "4 B per dense f32");
        assert_eq!(Message::Scalar(1.0).payload_bytes(), 8);
    }

    #[test]
    fn local_counters_track_payload_rounds_and_aborts() {
        let n = 2;
        let tp = Arc::new(LocalTransport::new(n));
        let tp1 = tp.clone();
        let h = std::thread::spawn(move || {
            tp1.allgather(1, Message::Floats(Arc::new(vec![0.0f32; 10])))
                .unwrap()
        });
        tp.allgather(0, Message::Floats(Arc::new(vec![0.0f32; 20])))
            .unwrap();
        h.join().unwrap();
        let c0 = tp.counters(0).expect("local is instrumented").snapshot();
        let c1 = tp.counters(1).unwrap().snapshot();
        assert_eq!(c0.payload_tx_bytes, 20 * 4);
        assert_eq!(c0.payload_rx_bytes, 10 * 4, "everything but our own entry");
        assert_eq!(c1.payload_tx_bytes, 10 * 4);
        assert_eq!(c1.payload_rx_bytes, 20 * 4);
        assert_eq!(c0.rounds_allgather, 1);
        assert_eq!(c0.rounds_rsag, 0);
        assert_eq!(c0.wire_tx_bytes, 0, "no socket, no wire account");
        assert!(tp.counters(5).is_none(), "out of range is None");
        tp.abort();
        assert_eq!(tp.counters(0).unwrap().snapshot().aborts, 1);
        assert_eq!(tp.counters(1).unwrap().snapshot().aborts, 1);
        // the elastic teardown path aborts defensively — repeats still
        // describe the one poisoning
        tp.abort();
        tp.abort_from(1);
        assert_eq!(tp.counters(0).unwrap().snapshot().aborts, 1);
    }

    #[test]
    fn attributed_abort_surfaces_peer_lost_with_the_rank() {
        let tp = Arc::new(LocalTransport::new(2));
        assert_eq!((tp.as_ref() as &dyn Transport).epoch(), 0);
        tp.abort_from(1);
        let ep = Endpoint::new(0, tp.as_ref());
        let err = ep.allgather_f64(0.0).unwrap_err();
        assert!(err.is_membership_fault(), "{err}");
        let msg = err.to_string();
        assert!(msg.contains("peer rank 1 lost"), "{msg}");
        // first attribution wins over later anonymous poisonings
        tp.abort();
        let err = ep.allgather_f64(0.0).unwrap_err().to_string();
        assert!(err.contains("peer rank 1 lost"), "{err}");
    }

    #[test]
    fn anonymous_abort_surfaces_the_poisoned_fault() {
        let tp = Arc::new(LocalTransport::new_at_epoch(2, 3));
        assert_eq!((tp.as_ref() as &dyn Transport).epoch(), 3);
        tp.abort();
        let ep = Endpoint::new(0, tp.as_ref());
        let err = ep.allgather_f64(0.0).unwrap_err();
        assert!(err.is_membership_fault(), "{err}");
        assert!(
            err.to_string().contains("transport poisoned by a failed worker"),
            "{err}"
        );
    }

    /// Strided sparse contribution with order-probe magnitudes: rank r
    /// selects positions r, r+n, r+2n, … below `len`, so selections
    /// are disjoint but every shard sees entries from several ranks.
    fn sparse_probe(rank: usize, round: usize, n: usize, len: usize) -> SparseVec {
        const VALS: [f32; 3] = [1.0e8, 1.0, -1.0e8];
        let mut sv = SparseVec::new();
        let mut pos = rank;
        while pos < len {
            sv.push(pos as u32, VALS[(rank + pos + round) % 3]);
            pos += n;
        }
        sv
    }

    #[test]
    fn sparse_rsag_matches_the_lockstep_twin_bit_for_bit() {
        // blocking and split-phase sparse rounds, capped and uncapped,
        // against the lock-step core — reduced entries AND residuals
        // must agree bitwise on every rank over many rounds
        let n = 3;
        let len = 14;
        let rounds = 12;
        let tp = Arc::new(LocalTransport::new(n));
        let mut handles = Vec::new();
        for rank in 0..n {
            let tp = tp.clone();
            handles.push(std::thread::spawn(move || {
                let ep = Endpoint::new(rank, tp.as_ref());
                let mut send = SparseBufPool::new();
                let mut scratch = SparseReduceScratch::new();
                let mut out = SparseVec::new();
                let mut residual = SparseVec::new();
                for round in 0..rounds {
                    let shard_k = if round % 3 == 0 { 0 } else { 2 };
                    let rd = SparseRound {
                        union_len: len,
                        shard_k,
                    };
                    let probe = sparse_probe(rank, round, n, len);
                    let mine = send.fill(|b| b.copy_from(&probe.idx, &probe.val));
                    if round % 2 == 0 {
                        ep.rsag_sparse(mine, rd, &mut scratch, &mut out, &mut residual)
                            .unwrap();
                    } else {
                        let pending = ep.rsag_sparse_start(mine, rd).unwrap();
                        assert_eq!(pending.rank(), rank);
                        pending
                            .finish(&mut scratch, &mut out, &mut residual)
                            .unwrap();
                    }
                    // the lock-step twin, rebuilt from the same inputs
                    let contribs: Vec<SparseVec> =
                        (0..n).map(|r| sparse_probe(r, round, n, len)).collect();
                    let net = crate::collectives::CostModel::paper_testbed(n);
                    let mut tw_scratch = SparseReduceScratch::new();
                    let mut tw_entries = SparseVec::new();
                    let mut tw_reduced = Vec::new();
                    let mut tw_residuals: Vec<SparseVec> =
                        (0..n).map(|_| SparseVec::new()).collect();
                    crate::collectives::sparse_shard_allreduce_lockstep(
                        &contribs,
                        len,
                        shard_k,
                        &net,
                        &mut tw_scratch,
                        &mut tw_entries,
                        &mut tw_reduced,
                        &mut tw_residuals,
                    );
                    assert_eq!(out.idx, tw_entries.idx, "rank {rank} round {round}");
                    let got: Vec<u32> = out.val.iter().map(|x| x.to_bits()).collect();
                    let want: Vec<u32> =
                        tw_entries.val.iter().map(|x| x.to_bits()).collect();
                    assert_eq!(got, want, "rank {rank} round {round} values");
                    assert_eq!(
                        residual.idx, tw_residuals[rank].idx,
                        "rank {rank} round {round} residual positions"
                    );
                    let got: Vec<u32> = residual.val.iter().map(|x| x.to_bits()).collect();
                    let want: Vec<u32> =
                        tw_residuals[rank].val.iter().map(|x| x.to_bits()).collect();
                    assert_eq!(got, want, "rank {rank} round {round} residual values");
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn sparse_rsag_shares_the_one_outstanding_round_budget() {
        let tp = LocalTransport::new(1);
        let dynamic: &dyn Transport = &tp;
        let rd = SparseRound {
            union_len: 4,
            shard_k: 0,
        };
        let mut sv = SparseVec::new();
        sv.push(1, 2.5);
        let pending = dynamic.rsag_sparse_start(0, Arc::new(sv), rd).unwrap();
        let err = dynamic
            .allgather_start(0, Message::Scalar(1.0))
            .err()
            .expect("mixed double start must be rejected")
            .to_string();
        assert!(err.contains("double-started"), "{err}");
        let err = dynamic
            .rsag_sparse_start(0, Arc::new(SparseVec::new()), rd)
            .err()
            .expect("sparse double start must be rejected")
            .to_string();
        assert!(err.contains("double-started"), "{err}");
        let mut scratch = SparseReduceScratch::new();
        let mut out = SparseVec::new();
        let mut residual = SparseVec::new();
        pending.finish(&mut scratch, &mut out, &mut residual).unwrap();
        assert_eq!(out.idx, vec![1]);
        assert_eq!(out.val, vec![2.5]);
        assert!(residual.is_empty(), "uncapped round has no residual");
    }

    #[test]
    fn dropped_pending_sparse_reduce_does_not_wedge_peers() {
        let n = 2;
        let rounds = 4;
        let len = 6;
        let tp = Arc::new(LocalTransport::new(n));
        let tp1 = tp.clone();
        let rd = SparseRound {
            union_len: len,
            shard_k: 0,
        };
        let peer = std::thread::spawn(move || {
            let ep = Endpoint::new(1, tp1.as_ref());
            let mut scratch = SparseReduceScratch::new();
            let mut out = SparseVec::new();
            let mut residual = SparseVec::new();
            for round in 0..rounds {
                let mut sv = SparseVec::new();
                sv.push(1, 1.0);
                ep.rsag_sparse(Arc::new(sv), rd, &mut scratch, &mut out, &mut residual)
                    .unwrap();
                // rank 0's entry lands in EVERY round, including the
                // one rank 0 abandoned
                assert_eq!(out.idx, vec![0, 1], "round {round}");
                assert_eq!(out.val, vec![(round + 1) as f32, 1.0], "round {round}");
            }
        });
        let ep = Endpoint::new(0, tp.as_ref());
        let mut scratch = SparseReduceScratch::new();
        let mut out = SparseVec::new();
        let mut residual = SparseVec::new();
        for round in 0..rounds {
            let mut sv = SparseVec::new();
            sv.push(0, (round + 1) as f32);
            if round == 1 {
                let pending = ep.rsag_sparse_start(Arc::new(sv), rd).unwrap();
                drop(pending); // walk away without finishing
            } else {
                ep.rsag_sparse(Arc::new(sv), rd, &mut scratch, &mut out, &mut residual)
                    .unwrap();
                assert_eq!(out.val, vec![(round + 1) as f32, 1.0]);
            }
        }
        peer.join().unwrap();
    }

    #[test]
    fn sparse_buf_pool_reuses_released_buffers() {
        let mut pool = SparseBufPool::new();
        let a = pool.fill(|b| b.push(3, 1.5));
        assert_eq!(a.idx, vec![3]);
        let a_ptr = Arc::as_ptr(&a);
        drop(a);
        let mut seen = false;
        for i in 0..6 {
            let b = pool.fill(|b| b.push(i, i as f32));
            seen |= Arc::as_ptr(&b) == a_ptr;
            assert_eq!(b.idx, vec![i], "cleared before refill");
        }
        assert!(seen, "released buffer must be recycled");
        let held = pool.fill(|b| b.push(7, 7.0));
        for i in 0..6 {
            let b = pool.fill(|b| b.push(i, i as f32));
            assert!(!Arc::ptr_eq(&b, &held), "live handle must not be reused");
        }
        assert_eq!(held.idx, vec![7]);
    }

    #[test]
    fn local_sparse_rsag_counters_track_entry_bytes_and_rounds() {
        let n = 2;
        let len = 8;
        let tp = Arc::new(LocalTransport::new(n));
        let rd = SparseRound {
            union_len: len,
            shard_k: 0,
        };
        let tp1 = tp.clone();
        let h = std::thread::spawn(move || {
            let mut sv = SparseVec::new();
            for i in 0..3 {
                sv.push(i * 2 + 1, 1.0);
            }
            let mut scratch = SparseReduceScratch::new();
            let mut out = SparseVec::new();
            let mut residual = SparseVec::new();
            tp1.rsag_sparse(1, Arc::new(sv), rd, &mut scratch, &mut out, &mut residual)
                .unwrap();
        });
        let mut sv = SparseVec::new();
        sv.push(0, 2.0);
        sv.push(4, 2.0);
        let mut scratch = SparseReduceScratch::new();
        let mut out = SparseVec::new();
        let mut residual = SparseVec::new();
        tp.rsag_sparse(0, Arc::new(sv), rd, &mut scratch, &mut out, &mut residual)
            .unwrap();
        h.join().unwrap();
        let c0 = tp.counters(0).unwrap().snapshot();
        let c1 = tp.counters(1).unwrap().snapshot();
        assert_eq!(c0.payload_tx_bytes, 2 * 8, "8 B per sparse entry");
        assert_eq!(c0.payload_rx_bytes, 3 * 8, "peer's entries only");
        assert_eq!(c1.payload_tx_bytes, 3 * 8);
        assert_eq!(c1.payload_rx_bytes, 2 * 8);
        assert_eq!(c0.rounds_rsag, 1);
        assert_eq!(c0.rounds_allgather, 0);
    }

    #[test]
    fn sparse_contribution_out_of_union_bounds_is_a_typed_error() {
        let tp = LocalTransport::new(1);
        let mut sv = SparseVec::new();
        sv.push(9, 1.0);
        let rd = SparseRound {
            union_len: 8,
            shard_k: 0,
        };
        let mut scratch = SparseReduceScratch::new();
        let mut out = SparseVec::new();
        let mut residual = SparseVec::new();
        let err = tp
            .rsag_sparse(0, Arc::new(sv), rd, &mut scratch, &mut out, &mut residual)
            .unwrap_err()
            .to_string();
        assert!(err.contains("union length"), "{err}");
    }

    #[test]
    fn local_rsag_counts_one_rsag_round_and_no_allgather_round() {
        let tp = LocalTransport::new(1);
        let dynamic: &dyn Transport = &tp;
        let mut shards = FloatBufPool::new();
        let mut out = Vec::new();
        dynamic
            .reduce_scatter_allgather(0, Arc::new(vec![1.0f32; 8]), &mut shards, &mut out)
            .unwrap();
        let c = tp.counters(0).unwrap().snapshot();
        assert_eq!(c.rounds_rsag, 1);
        assert_eq!(
            c.rounds_allgather, 0,
            "the internal shard hop is not a collective round"
        );
    }
}
