//! Message transport between rank workers.
//!
//! [`Transport`] is the abstraction the per-rank collectives run over: a
//! synchronous, rank-addressed all-gather (every collective in Alg. 1 —
//! metadata all-gather, padded payload all-gather, sparse all-reduce
//! contributions, leader broadcast — decomposes into "each rank
//! contributes one message, every rank receives the rank-indexed
//! vector"). Implementations move the bytes; the α–β [`CostModel`]
//! separately charges what the operation *would* cost on the modeled
//! wire, so data movement and wire-clock accounting stay decoupled.
//!
//! [`LocalTransport`] is the first implementation: in-process rendezvous
//! for one OS thread per rank, built on a generation-counted slot board
//! (mutex + condvar). Every round each rank deposits its message; the
//! last arrival publishes the full board and wakes the others. A rank
//! can only enter round `g+1` after consuming round `g`, so the
//! published board is never overwritten early. A failed worker poisons
//! the transport ([`Transport::abort`]) so peers error out instead of
//! deadlocking at the rendezvous.
//!
//! [CostModel]: crate::collectives::CostModel

use crate::coordinator::SelectOutput;
use crate::error::{Error, Result};
use std::sync::{Condvar, Mutex};

/// One rank's contribution to a collective round.
#[derive(Clone, Debug, PartialEq)]
pub enum Message {
    /// Selected (idx, val) pairs — the payload all-gather (its length is
    /// simultaneously the `k_i` metadata).
    Selection(SelectOutput),
    /// Dense f32 payload — sparse all-reduce contributions.
    Floats(Vec<f32>),
    /// One f64 — timing metadata and diagnostics (select wall time,
    /// error norms).
    Scalar(f64),
}

/// Rank-addressed synchronous collectives. Implementations must be
/// callable concurrently from one thread per rank.
pub trait Transport: Send + Sync {
    /// Cluster size.
    fn n_ranks(&self) -> usize;

    /// Synchronous all-gather: rank `rank` contributes `msg` and receives
    /// every rank's message, rank-indexed. All ranks must call this the
    /// same number of times in the same order (enforced by construction:
    /// workers run identical control flow off replicated state).
    fn allgather(&self, rank: usize, msg: Message) -> Result<Vec<Message>>;

    /// Rendezvous barrier (default: a scalar all-gather).
    fn barrier(&self, rank: usize) -> Result<()> {
        self.allgather(rank, Message::Scalar(0.0)).map(|_| ())
    }

    /// Poison the transport: wake every waiter with an error. Called by a
    /// worker that is about to exit with a failure so peers don't block
    /// forever at the next rendezvous.
    fn abort(&self);
}

struct Board {
    slots: Vec<Option<Message>>,
    arrived: usize,
    generation: u64,
    published: Vec<Message>,
    poisoned: bool,
}

/// In-process transport for one OS thread per rank.
pub struct LocalTransport {
    n: usize,
    board: Mutex<Board>,
    cv: Condvar,
}

impl LocalTransport {
    /// Transport for `n` ranks.
    pub fn new(n: usize) -> Self {
        LocalTransport {
            n,
            board: Mutex::new(Board {
                slots: (0..n).map(|_| None).collect(),
                arrived: 0,
                generation: 0,
                published: Vec::new(),
                poisoned: false,
            }),
            cv: Condvar::new(),
        }
    }
}

impl Transport for LocalTransport {
    fn n_ranks(&self) -> usize {
        self.n
    }

    fn allgather(&self, rank: usize, msg: Message) -> Result<Vec<Message>> {
        if rank >= self.n {
            return Err(Error::invalid(format!(
                "rank {rank} out of range (n = {})",
                self.n
            )));
        }
        let mut b = self.board.lock().unwrap();
        if b.poisoned {
            return Err(Error::invariant("transport poisoned by a failed worker"));
        }
        debug_assert!(b.slots[rank].is_none(), "rank {rank} double-deposited");
        let my_gen = b.generation;
        b.slots[rank] = Some(msg);
        b.arrived += 1;
        if b.arrived == self.n {
            // last arrival: publish the board, open the next round
            let msgs: Vec<Message> = b.slots.iter_mut().map(|s| s.take().unwrap()).collect();
            b.published = msgs;
            b.arrived = 0;
            b.generation = b.generation.wrapping_add(1);
            self.cv.notify_all();
        } else {
            while b.generation == my_gen && !b.poisoned {
                b = self.cv.wait(b).unwrap();
            }
            if b.poisoned {
                return Err(Error::invariant("transport poisoned by a failed worker"));
            }
        }
        // each rank receives its own copy — the real data movement
        Ok(b.published.clone())
    }

    fn abort(&self) {
        let mut b = self.board.lock().unwrap();
        b.poisoned = true;
        self.cv.notify_all();
    }
}

/// One rank's handle onto a transport: typed all-gather helpers that
/// unwrap the [`Message`] envelope (an envelope mismatch means workers
/// diverged in control flow — an invariant error, never silent).
pub struct Endpoint<'a> {
    /// This rank.
    pub rank: usize,
    tp: &'a dyn Transport,
}

impl<'a> Endpoint<'a> {
    /// Handle for `rank` over `tp`.
    pub fn new(rank: usize, tp: &'a dyn Transport) -> Self {
        Endpoint { rank, tp }
    }

    /// Cluster size.
    pub fn n_ranks(&self) -> usize {
        self.tp.n_ranks()
    }

    /// Underlying transport (for `abort`).
    pub fn transport(&self) -> &dyn Transport {
        self.tp
    }

    /// All-gather per-rank selections (metadata + payload in one round).
    pub fn allgather_select(&self, mine: SelectOutput) -> Result<Vec<SelectOutput>> {
        let msgs = self.tp.allgather(self.rank, Message::Selection(mine))?;
        msgs.into_iter()
            .map(|m| match m {
                Message::Selection(s) => Ok(s),
                other => Err(envelope_mismatch("Selection", &other)),
            })
            .collect()
    }

    /// All-gather dense f32 payloads (all-reduce contributions).
    pub fn allgather_floats(&self, mine: Vec<f32>) -> Result<Vec<Vec<f32>>> {
        let msgs = self.tp.allgather(self.rank, Message::Floats(mine))?;
        msgs.into_iter()
            .map(|m| match m {
                Message::Floats(v) => Ok(v),
                other => Err(envelope_mismatch("Floats", &other)),
            })
            .collect()
    }

    /// All-gather one f64 per rank (timings, norms).
    pub fn allgather_f64(&self, mine: f64) -> Result<Vec<f64>> {
        let msgs = self.tp.allgather(self.rank, Message::Scalar(mine))?;
        msgs.into_iter()
            .map(|m| match m {
                Message::Scalar(x) => Ok(x),
                other => Err(envelope_mismatch("Scalar", &other)),
            })
            .collect()
    }

    /// Barrier.
    pub fn barrier(&self) -> Result<()> {
        self.tp.barrier(self.rank)
    }
}

/// RAII guard for worker threads: if the holding thread unwinds (a
/// panic, not an `Err`), the transport is poisoned so peer ranks error
/// out of their rendezvous instead of blocking forever. The explicit
/// `Err` paths call [`Transport::abort`] themselves; this covers the
/// path no `if out.is_err()` check can.
pub(crate) struct AbortOnPanic<'a>(pub &'a dyn Transport);

impl Drop for AbortOnPanic<'_> {
    fn drop(&mut self) {
        if std::thread::panicking() {
            self.0.abort();
        }
    }
}

fn envelope_mismatch(want: &str, got: &Message) -> Error {
    let got = match got {
        Message::Selection(_) => "Selection",
        Message::Floats(_) => "Floats",
        Message::Scalar(_) => "Scalar",
    };
    Error::invariant(format!(
        "transport envelope mismatch: expected {want}, got {got} — workers diverged"
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn single_rank_allgather_is_identity() {
        let tp = LocalTransport::new(1);
        let ep = Endpoint::new(0, &tp);
        let got = ep.allgather_f64(2.5).unwrap();
        assert_eq!(got, vec![2.5]);
        // rounds are reusable
        let got = ep.allgather_f64(3.5).unwrap();
        assert_eq!(got, vec![3.5]);
    }

    #[test]
    fn multi_rank_allgather_is_rank_indexed_over_rounds() {
        let n = 4;
        let rounds = 25;
        let tp = Arc::new(LocalTransport::new(n));
        let mut handles = Vec::new();
        for rank in 0..n {
            let tp = tp.clone();
            handles.push(std::thread::spawn(move || {
                let ep = Endpoint::new(rank, tp.as_ref());
                for round in 0..rounds {
                    let mine = (rank * 1000 + round) as f64;
                    let got = ep.allgather_f64(mine).unwrap();
                    let want: Vec<f64> =
                        (0..n).map(|r| (r * 1000 + round) as f64).collect();
                    assert_eq!(got, want, "rank {rank} round {round}");
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn selections_roundtrip() {
        let n = 2;
        let tp = Arc::new(LocalTransport::new(n));
        let mk = |r: usize| SelectOutput {
            idx: vec![r as u32, 10 + r as u32],
            val: vec![r as f32, -(r as f32)],
        };
        let mut handles = Vec::new();
        for rank in 0..n {
            let tp = tp.clone();
            let mine = mk(rank);
            handles.push(std::thread::spawn(move || {
                let ep = Endpoint::new(rank, tp.as_ref());
                ep.allgather_select(mine).unwrap()
            }));
        }
        for h in handles {
            let outs = h.join().unwrap();
            assert_eq!(outs.len(), n);
            for (r, o) in outs.iter().enumerate() {
                assert_eq!(*o, mk(r));
            }
        }
    }

    #[test]
    fn abort_unblocks_waiters_with_error() {
        let tp = Arc::new(LocalTransport::new(2));
        let tp2 = tp.clone();
        let waiter = std::thread::spawn(move || {
            let ep = Endpoint::new(0, tp2.as_ref());
            ep.allgather_f64(1.0)
        });
        // give the waiter time to block, then poison instead of joining
        std::thread::sleep(std::time::Duration::from_millis(20));
        tp.abort();
        let res = waiter.join().unwrap();
        assert!(res.is_err(), "poisoned transport must error, not hang");
        // later calls fail fast
        let ep = Endpoint::new(1, tp.as_ref());
        assert!(ep.allgather_f64(2.0).is_err());
    }

    #[test]
    fn out_of_range_rank_rejected() {
        let tp = LocalTransport::new(2);
        let ep = Endpoint::new(5, &tp);
        assert!(ep.allgather_f64(0.0).is_err());
    }

    #[test]
    fn panicking_worker_poisons_transport_via_guard() {
        let tp = Arc::new(LocalTransport::new(2));
        let tp2 = tp.clone();
        let panicker = std::thread::spawn(move || {
            let _guard = AbortOnPanic(tp2.as_ref() as &dyn Transport);
            panic!("worker died without returning an Err");
        });
        assert!(panicker.join().is_err());
        // the surviving rank must error out, not block forever
        let ep = Endpoint::new(0, tp.as_ref());
        assert!(ep.allgather_f64(0.0).is_err());
    }
}
