//! Message transport between rank workers.
//!
//! [`Transport`] is the abstraction the per-rank collectives run over: a
//! synchronous, rank-addressed all-gather (every collective in Alg. 1 —
//! metadata all-gather, padded payload all-gather, sparse all-reduce
//! contributions, leader broadcast — decomposes into "each rank
//! contributes one message, every rank receives the rank-indexed
//! vector"). Implementations move the bytes; the α–β [`CostModel`]
//! separately charges what the operation *would* cost on the modeled
//! wire, so data movement and wire-clock accounting stay decoupled.
//!
//! **Zero-copy fan-out.** Payloads are reference-counted
//! ([`Message::Selection`] holds `Arc<SelectOutput>`, [`Message::Floats`]
//! holds `Arc<Vec<f32>>`) and [`Transport::allgather`] returns the whole
//! rank-indexed board as one shared `Arc<[Message]>` slab. Handing the
//! board to n ranks is therefore n refcount bumps — O(n) — instead of n
//! deep copies of an n-message board — O(n²·k) element copies, which is
//! what the naive `Vec<Message>` design cost per round. The *modeled*
//! α–β clock still charges the real byte volume each collective would
//! move on a wire (the padded payload, every rank's contribution), so
//! traces are bit-identical to the copying implementation; only the
//! harness overhead changes.
//!
//! [`LocalTransport`] is the in-process implementation: a rendezvous for
//! one OS thread per rank, built on a generation-counted slot board
//! (mutex + condvar). Every round each rank deposits its message; the
//! last arrival publishes the full board and wakes the others. A rank
//! can only enter round `g+1` after consuming round `g`, so the
//! published board is never overwritten early. Published slabs are
//! double-buffered and recycled once every rank has moved two rounds on,
//! so a steady-state round performs **zero heap allocations**
//! (`rust/tests/alloc_regression.rs` pins this). A failed worker poisons
//! the transport ([`Transport::abort`]) so peers error out instead of
//! deadlocking at the rendezvous.
//!
//! [CostModel]: crate::collectives::CostModel

use crate::coordinator::SelectOutput;
use crate::error::{Error, Result};
use std::sync::{Arc, Condvar, Mutex};

/// One rank's contribution to a collective round. Payloads are behind
/// `Arc`s so boards fan out by refcount, not by copy; `Clone` is O(1).
#[derive(Clone, Debug, PartialEq)]
pub enum Message {
    /// Selected (idx, val) pairs — the payload all-gather (its length is
    /// simultaneously the `k_i` metadata).
    Selection(Arc<SelectOutput>),
    /// Dense f32 payload — sparse all-reduce contributions.
    Floats(Arc<Vec<f32>>),
    /// One f64 — timing metadata and diagnostics (select wall time,
    /// error norms).
    Scalar(f64),
}

/// Rank-addressed synchronous collectives. Implementations must be
/// callable concurrently from one thread per rank.
pub trait Transport: Send + Sync {
    /// Cluster size.
    fn n_ranks(&self) -> usize;

    /// Synchronous all-gather: rank `rank` contributes `msg` and receives
    /// every rank's message, rank-indexed, as one shared slab. All ranks
    /// must call this the same number of times in the same order
    /// (enforced by construction: workers run identical control flow off
    /// replicated state).
    fn allgather(&self, rank: usize, msg: Message) -> Result<Arc<[Message]>>;

    /// Rendezvous barrier (default: a scalar all-gather).
    fn barrier(&self, rank: usize) -> Result<()> {
        self.allgather(rank, Message::Scalar(0.0)).map(|_| ())
    }

    /// Poison the transport: wake every waiter with an error. Called by a
    /// worker that is about to exit with a failure so peers don't block
    /// forever at the next rendezvous.
    fn abort(&self);
}

struct Board {
    slots: Vec<Option<Message>>,
    arrived: usize,
    generation: u64,
    published: Arc<[Message]>,
    /// The round-before-last's slab, kept for recycling: once every rank
    /// has deposited round `g+1` (a precondition of publishing it), no
    /// rank can still hold a reference to round `g-1`'s board, so its
    /// slab is uniquely owned again and can be overwritten in place.
    spare: Option<Arc<[Message]>>,
    poisoned: bool,
}

/// In-process transport for one OS thread per rank.
pub struct LocalTransport {
    n: usize,
    board: Mutex<Board>,
    cv: Condvar,
}

impl LocalTransport {
    /// Transport for `n` ranks.
    pub fn new(n: usize) -> Self {
        LocalTransport {
            n,
            board: Mutex::new(Board {
                slots: (0..n).map(|_| None).collect(),
                arrived: 0,
                generation: 0,
                published: Vec::new().into(),
                spare: None,
                poisoned: false,
            }),
            cv: Condvar::new(),
        }
    }
}

impl Transport for LocalTransport {
    fn n_ranks(&self) -> usize {
        self.n
    }

    fn allgather(&self, rank: usize, msg: Message) -> Result<Arc<[Message]>> {
        if rank >= self.n {
            return Err(Error::invalid(format!(
                "rank {rank} out of range (n = {})",
                self.n
            )));
        }
        let mut b = self.board.lock().unwrap();
        if b.poisoned {
            return Err(Error::invariant("transport poisoned by a failed worker"));
        }
        if b.slots[rank].is_some() {
            // a real invariant error in every build profile — a silent
            // overwrite here would corrupt a peer's board in release mode
            return Err(Error::invariant(format!(
                "rank {rank} double-deposited in round {}",
                b.generation
            )));
        }
        let my_gen = b.generation;
        b.slots[rank] = Some(msg);
        b.arrived += 1;
        if b.arrived == self.n {
            // last arrival: publish the board, open the next round
            let board = &mut *b;
            let recycled = board.spare.take().and_then(|mut slab| {
                if slab.len() == board.slots.len() && Arc::get_mut(&mut slab).is_some() {
                    Some(slab)
                } else {
                    None // a caller retained an old board; fall back
                }
            });
            let new_board: Arc<[Message]> = match recycled {
                Some(mut slab) => {
                    let dst = Arc::get_mut(&mut slab).expect("uniqueness checked above");
                    for (d, s) in dst.iter_mut().zip(board.slots.iter_mut()) {
                        *d = s.take().expect("all slots deposited");
                    }
                    slab
                }
                None => board
                    .slots
                    .iter_mut()
                    .map(|s| s.take().expect("all slots deposited"))
                    .collect(),
            };
            board.spare = Some(std::mem::replace(&mut board.published, new_board));
            board.arrived = 0;
            board.generation = board.generation.wrapping_add(1);
            self.cv.notify_all();
        } else {
            while b.generation == my_gen && !b.poisoned {
                b = self.cv.wait(b).unwrap();
            }
            if b.poisoned {
                return Err(Error::invariant("transport poisoned by a failed worker"));
            }
        }
        // every rank shares the one published slab — a refcount bump, not
        // a copy; the modeled wire cost is charged by the collectives
        Ok(b.published.clone())
    }

    fn abort(&self) {
        let mut b = self.board.lock().unwrap();
        b.poisoned = true;
        self.cv.notify_all();
    }
}

/// Rotating pool of reusable `Arc<Vec<f32>>` send buffers for
/// [`Message::Floats`] contributions.
///
/// A buffer handed out in round `g` is shared with the peers (who drop
/// their board clones before depositing round `g+1`) and with
/// [`LocalTransport`] itself, which keeps the round-`g` slab alive as
/// its recycling spare until the publish of round `g+2`. The buffer is
/// therefore guaranteed uniquely owned again only at its owner's round
/// `g+3` send — exactly the reuse distance the THREE-slot rotation
/// provides (a 2-slot pool would find the transport's spare still
/// holding the Arc and fall back to allocating every round). If a
/// caller retains a board even longer the pool transparently falls back
/// to a fresh buffer, so reuse is an optimization, never a correctness
/// assumption.
pub struct FloatBufPool {
    bufs: [Arc<Vec<f32>>; 3],
    next: usize,
}

impl FloatBufPool {
    /// Empty pool; buffers grow to the working-set size on first use.
    pub fn new() -> Self {
        FloatBufPool {
            bufs: [
                Arc::new(Vec::new()),
                Arc::new(Vec::new()),
                Arc::new(Vec::new()),
            ],
            next: 0,
        }
    }

    /// Hand out a shareable buffer, cleared and then filled by `fill`.
    pub fn fill(&mut self, fill: impl FnOnce(&mut Vec<f32>)) -> Arc<Vec<f32>> {
        let idx = self.next;
        self.next = (idx + 1) % self.bufs.len();
        let slot = &mut self.bufs[idx];
        if Arc::get_mut(slot).is_none() {
            // a peer still holds the handle from this slot's last round
            // (only possible outside the steady state, e.g. a retained
            // board) — fall back to a fresh buffer
            *slot = Arc::new(Vec::new());
        }
        let buf = Arc::get_mut(slot).expect("slot is uniquely owned here");
        buf.clear();
        fill(buf);
        Arc::clone(slot)
    }
}

impl Default for FloatBufPool {
    fn default() -> Self {
        Self::new()
    }
}

/// One rank's handle onto a transport: typed all-gather helpers that
/// unwrap the [`Message`] envelope (an envelope mismatch means workers
/// diverged in control flow — an invariant error, never silent).
pub struct Endpoint<'a> {
    /// This rank.
    pub rank: usize,
    tp: &'a dyn Transport,
}

impl<'a> Endpoint<'a> {
    /// Handle for `rank` over `tp`.
    pub fn new(rank: usize, tp: &'a dyn Transport) -> Self {
        Endpoint { rank, tp }
    }

    /// Cluster size.
    pub fn n_ranks(&self) -> usize {
        self.tp.n_ranks()
    }

    /// Underlying transport (for `abort`).
    pub fn transport(&self) -> &dyn Transport {
        self.tp
    }

    /// Raw all-gather: contribute `msg`, receive the shared rank-indexed
    /// board. The allocation-free primitive the per-rank collectives
    /// ([`crate::collectives::ranked`]) build on.
    pub fn allgather(&self, msg: Message) -> Result<Arc<[Message]>> {
        self.tp.allgather(self.rank, msg)
    }

    /// All-gather per-rank selections (metadata + payload in one round).
    /// The returned entries share the senders' buffers.
    pub fn allgather_select(&self, mine: Arc<SelectOutput>) -> Result<Vec<Arc<SelectOutput>>> {
        let board = self.tp.allgather(self.rank, Message::Selection(mine))?;
        board
            .iter()
            .map(|m| match m {
                Message::Selection(s) => Ok(Arc::clone(s)),
                other => Err(envelope_mismatch("Selection", other)),
            })
            .collect()
    }

    /// All-gather dense f32 payloads (all-reduce contributions). The
    /// returned entries share the senders' buffers.
    pub fn allgather_floats(&self, mine: Arc<Vec<f32>>) -> Result<Vec<Arc<Vec<f32>>>> {
        let board = self.tp.allgather(self.rank, Message::Floats(mine))?;
        board
            .iter()
            .map(|m| match m {
                Message::Floats(v) => Ok(Arc::clone(v)),
                other => Err(envelope_mismatch("Floats", other)),
            })
            .collect()
    }

    /// All-gather one f64 per rank (timings, norms).
    pub fn allgather_f64(&self, mine: f64) -> Result<Vec<f64>> {
        self.allgather_f64_fold(mine, Vec::with_capacity(self.n_ranks()), |mut acc, x| {
            acc.push(x);
            acc
        })
    }

    /// All-gather one f64 per rank and fold the rank-ordered values
    /// without materializing them — the allocation-free form for sums
    /// and maxima on the hot path.
    pub fn allgather_f64_fold<T>(
        &self,
        mine: f64,
        init: T,
        mut f: impl FnMut(T, f64) -> T,
    ) -> Result<T> {
        let board = self.tp.allgather(self.rank, Message::Scalar(mine))?;
        let mut acc = init;
        for m in board.iter() {
            match m {
                Message::Scalar(x) => acc = f(acc, *x),
                other => return Err(envelope_mismatch("Scalar", other)),
            }
        }
        Ok(acc)
    }

    /// Barrier.
    pub fn barrier(&self) -> Result<()> {
        self.tp.barrier(self.rank)
    }
}

/// Publish a completed round's slot board as an `Arc<[Message]>` slab,
/// recycling the previous round's slab when the caller has dropped its
/// clone (the per-rank twin of [`LocalTransport`]'s double-buffered
/// rotation, shared by both ring transports): `last` holds our clone of
/// the previously published board; if it is uniquely owned again it is
/// refilled in place, otherwise a fresh slab is allocated. Every slot
/// must be `Some` (the round is complete); slots are left `None` for
/// the next round.
pub(crate) fn publish_recycled(
    slots: &mut [Option<Message>],
    last: &mut Option<Arc<[Message]>>,
) -> Arc<[Message]> {
    let n = slots.len();
    let recycled = last.take().and_then(|mut slab| {
        if slab.len() == n && Arc::get_mut(&mut slab).is_some() {
            Some(slab)
        } else {
            None // a caller retained an old board; fall back
        }
    });
    let board: Arc<[Message]> = match recycled {
        Some(mut slab) => {
            let dst = Arc::get_mut(&mut slab).expect("uniqueness checked above");
            for (d, s) in dst.iter_mut().zip(slots.iter_mut()) {
                *d = s.take().expect("completed round fills every slot");
            }
            slab
        }
        None => slots
            .iter_mut()
            .map(|s| s.take().expect("completed round fills every slot"))
            .collect(),
    };
    *last = Some(Arc::clone(&board));
    board
}

/// RAII guard for worker threads: if the holding thread unwinds (a
/// panic, not an `Err`), the transport is poisoned so peer ranks error
/// out of their rendezvous instead of blocking forever. The explicit
/// `Err` paths call [`Transport::abort`] themselves; this covers the
/// path no `if out.is_err()` check can.
pub(crate) struct AbortOnPanic<'a>(pub &'a dyn Transport);

impl Drop for AbortOnPanic<'_> {
    fn drop(&mut self) {
        if std::thread::panicking() {
            self.0.abort();
        }
    }
}

pub(crate) fn envelope_mismatch(want: &str, got: &Message) -> Error {
    let got = match got {
        Message::Selection(_) => "Selection",
        Message::Floats(_) => "Floats",
        Message::Scalar(_) => "Scalar",
    };
    Error::invariant(format!(
        "transport envelope mismatch: expected {want}, got {got} — workers diverged"
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn single_rank_allgather_is_identity() {
        let tp = LocalTransport::new(1);
        let ep = Endpoint::new(0, &tp);
        let got = ep.allgather_f64(2.5).unwrap();
        assert_eq!(got, vec![2.5]);
        // rounds are reusable
        let got = ep.allgather_f64(3.5).unwrap();
        assert_eq!(got, vec![3.5]);
    }

    #[test]
    fn multi_rank_allgather_is_rank_indexed_over_rounds() {
        let n = 4;
        let rounds = 25;
        let tp = Arc::new(LocalTransport::new(n));
        let mut handles = Vec::new();
        for rank in 0..n {
            let tp = tp.clone();
            handles.push(std::thread::spawn(move || {
                let ep = Endpoint::new(rank, tp.as_ref());
                for round in 0..rounds {
                    let mine = (rank * 1000 + round) as f64;
                    let got = ep.allgather_f64(mine).unwrap();
                    let want: Vec<f64> = (0..n).map(|r| (r * 1000 + round) as f64).collect();
                    assert_eq!(got, want, "rank {rank} round {round}");
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn selections_roundtrip() {
        let n = 2;
        let tp = Arc::new(LocalTransport::new(n));
        let mk = |r: usize| SelectOutput {
            idx: vec![r as u32, 10 + r as u32],
            val: vec![r as f32, -(r as f32)],
        };
        let mut handles = Vec::new();
        for rank in 0..n {
            let tp = tp.clone();
            let mine = Arc::new(mk(rank));
            handles.push(std::thread::spawn(move || {
                let ep = Endpoint::new(rank, tp.as_ref());
                ep.allgather_select(mine).unwrap()
            }));
        }
        for h in handles {
            let outs = h.join().unwrap();
            assert_eq!(outs.len(), n);
            for (r, o) in outs.iter().enumerate() {
                assert_eq!(o.as_ref(), &mk(r));
            }
        }
    }

    #[test]
    fn ranks_share_one_board_slab() {
        // the O(n) fan-out claim at its root: both ranks' boards are the
        // SAME allocation, and a shared payload is the sender's buffer
        let n = 2;
        let tp = Arc::new(LocalTransport::new(n));
        let payload = Arc::new(vec![1.0f32, 2.0]);
        let sent = Arc::clone(&payload);
        let tp1 = tp.clone();
        let h = std::thread::spawn(move || tp1.allgather(1, Message::Floats(sent)).unwrap());
        let board0 = tp.allgather(0, Message::Floats(Arc::new(vec![0.5]))).unwrap();
        let board1 = h.join().unwrap();
        assert!(
            Arc::ptr_eq(&board0, &board1),
            "ranks must share one published slab"
        );
        match &board0[1] {
            Message::Floats(v) => {
                assert!(Arc::ptr_eq(v, &payload), "payload must not be copied")
            }
            other => panic!("wrong envelope {other:?}"),
        }
    }

    #[test]
    fn double_deposit_is_a_typed_error_in_all_builds() {
        let tp = Arc::new(LocalTransport::new(2));
        let tp2 = tp.clone();
        // rank 0 deposits and blocks waiting for rank 1 ...
        let blocked = std::thread::spawn(move || tp2.allgather(0, Message::Scalar(1.0)));
        std::thread::sleep(Duration::from_millis(30));
        // ... and a buggy second caller for rank 0 must get a typed
        // error, not silently overwrite the slot (this used to be a
        // debug_assert — release builds corrupted the board)
        let err = tp
            .allgather(0, Message::Scalar(2.0))
            .unwrap_err()
            .to_string();
        assert!(err.contains("double-deposited"), "{err}");
        tp.abort();
        assert!(blocked.join().unwrap().is_err());
    }

    #[test]
    fn abort_unblocks_waiters_with_error() {
        let tp = Arc::new(LocalTransport::new(2));
        let tp2 = tp.clone();
        let waiter = std::thread::spawn(move || {
            let ep = Endpoint::new(0, tp2.as_ref());
            ep.allgather_f64(1.0)
        });
        // give the waiter time to block, then poison instead of joining
        std::thread::sleep(Duration::from_millis(20));
        tp.abort();
        let res = waiter.join().unwrap();
        assert!(res.is_err(), "poisoned transport must error, not hang");
        // later calls fail fast
        let ep = Endpoint::new(1, tp.as_ref());
        assert!(ep.allgather_f64(2.0).is_err());
    }

    #[test]
    fn out_of_range_rank_rejected() {
        let tp = LocalTransport::new(2);
        let ep = Endpoint::new(5, &tp);
        assert!(ep.allgather_f64(0.0).is_err());
    }

    #[test]
    fn float_buf_pool_reuses_released_buffers() {
        let mut pool = FloatBufPool::new();
        let a = pool.fill(|b| b.extend_from_slice(&[1.0, 2.0]));
        assert_eq!(*a, vec![1.0, 2.0]);
        let a_ptr = Arc::as_ptr(&a);
        drop(a);
        // cycle through the rotation; the released slot must come back
        let mut seen = false;
        for i in 0..6 {
            let b = pool.fill(|b| b.push(i as f32));
            seen |= Arc::as_ptr(&b) == a_ptr;
            assert_eq!(*b, vec![i as f32], "cleared before refill");
        }
        assert!(seen, "released buffer must be recycled");
        // a retained buffer is never clobbered
        let held = pool.fill(|b| b.push(7.0));
        for i in 0..6 {
            let b = pool.fill(|b| b.push(i as f32));
            assert!(!Arc::ptr_eq(&b, &held), "live handle must not be reused");
        }
        assert_eq!(*held, vec![7.0]);
    }

    #[test]
    fn panicking_worker_poisons_transport_via_guard() {
        let tp = Arc::new(LocalTransport::new(2));
        let tp2 = tp.clone();
        let panicker = std::thread::spawn(move || {
            let _guard = AbortOnPanic(tp2.as_ref() as &dyn Transport);
            panic!("worker died without returning an Err");
        });
        assert!(panicker.join().is_err());
        // the surviving rank must error out, not block forever
        let ep = Endpoint::new(0, tp.as_ref());
        assert!(ep.allgather_f64(0.0).is_err());
    }
}
